"""Training loop: checkpoint/restart, preemption-safe, metric logging."""
from __future__ import annotations

import dataclasses
import signal
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, TokenPipeline
from repro.models.model import Model
from repro.training.train_step import (TrainConfig, init_train_state,
                                       make_train_step)


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0


def train_loop(model: Model, train_cfg: TrainConfig, data_cfg: DataConfig,
               loop_cfg: LoopConfig, *, jit_kwargs: dict | None = None,
               log=print) -> dict:
    """Run (or resume) training; returns the final state and loss history."""
    step_fn = make_train_step(model, train_cfg)
    step_fn = jax.jit(step_fn, donate_argnums=(0,), **(jit_kwargs or {}))
    pipeline = TokenPipeline(data_cfg)

    state = init_train_state(model, jax.random.PRNGKey(loop_cfg.seed),
                             train_cfg)
    start_step = 0
    ckpt = None
    if loop_cfg.ckpt_dir:
        ckpt = Checkpointer(loop_cfg.ckpt_dir)
        if ckpt.latest_step() is not None:
            state, start_step = ckpt.restore(None, state)
            log(f"[train] resumed from step {start_step}")

    # preemption safety: SIGTERM triggers an emergency checkpoint
    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True
    prev = signal.signal(signal.SIGTERM, _handler)

    losses = []
    t0 = time.time()
    try:
        for step in range(start_step, loop_cfg.total_steps):
            batch = jax.tree_util.tree_map(jax.numpy.asarray,
                                           pipeline.batch(step))
            state, metrics = step_fn(state, batch)
            if step % loop_cfg.log_every == 0 or \
                    step == loop_cfg.total_steps - 1:
                loss = float(metrics["loss"])
                losses.append((step, loss))
                log(f"[train] step {step} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"({time.time()-t0:.1f}s)")
            if ckpt and (step + 1) % loop_cfg.ckpt_every == 0:
                ckpt.save_async(step + 1, state)
            if preempted["flag"]:
                if ckpt:
                    ckpt.wait()
                    ckpt.save(step + 1, state)
                    log(f"[train] preempted: emergency checkpoint @ {step+1}")
                break
    finally:
        signal.signal(signal.SIGTERM, prev)
        if ckpt:
            ckpt.wait()
    return {"state": state, "losses": losses}
