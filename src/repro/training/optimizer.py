"""Optimizers (AdamW, Adafactor) and LR schedules (cosine, WSD).

Implemented from scratch (no optax in this container).  Both optimizers
keep their state in a pytree mirroring the params, so FSDP sharding rules
apply transparently (state inherits each param's logical axes).

Adafactor (factored second moment) is what lets llama3-405b train on a
single 256-chip v5e pod: 4 bytes/param of fp32 master + O(rows+cols)
statistics instead of Adam's 8 bytes/param of moments.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"             # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    min_dim_factored: int = 128
    # schedule
    schedule: str = "cosine"        # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10000
    stable_fraction: float = 0.8    # WSD: fraction of steps at peak LR
    min_lr_ratio: float = 0.1


# --------------------------------------------------------------------------
# LR schedules
# --------------------------------------------------------------------------
def schedule_fn(cfg: OptimizerConfig) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        if cfg.warmup_steps > 0:
            warm = jnp.minimum((step + 1.0) / cfg.warmup_steps, 1.0)
        else:
            warm = jnp.ones(())
        if cfg.schedule == "constant":
            return cfg.lr * warm
        if cfg.schedule == "wsd":
            # MiniCPM warmup-stable-decay: warmup, long stable plateau,
            # then (1 - sqrt-like) decay to min_lr.
            stable_end = cfg.total_steps * cfg.stable_fraction
            decay_span = jnp.maximum(cfg.total_steps - stable_end, 1.0)
            frac = jnp.clip((step - stable_end) / decay_span, 0.0, 1.0)
            decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
            return cfg.lr * warm * decay
        # cosine
        prog = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return cfg.lr * warm * (cfg.min_lr_ratio
                                + (1 - cfg.min_lr_ratio) * cos)
    return fn


# --------------------------------------------------------------------------
# Gradient utilities
# --------------------------------------------------------------------------
def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float
                        ) -> tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------
def adamw_init(params: PyTree) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros32, params),
        "nu": jax.tree_util.tree_map(zeros32, params),
        "master": jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params),
    }


def adamw_update(cfg: OptimizerConfig, grads, state, step):
    lr = schedule_fn(cfg)(step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, mu, nu, master):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        update = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if master.ndim >= 2:          # decay matrices only
            update = update + cfg.weight_decay * master
        master = master - lr * update
        return mu, nu, master

    flat = jax.tree_util.tree_map(upd, grads, state["mu"], state["nu"],
                                  state["master"])
    mu = jax.tree_util.tree_map(lambda x: x[0], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree_util.tree_map(lambda x: x[1], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree_util.tree_map(lambda x: x[2], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return {"mu": mu, "nu": nu, "master": master}


# --------------------------------------------------------------------------
# Adafactor (factored second moment; fp32 master, no first moment)
# --------------------------------------------------------------------------
def _factored(shape: tuple[int, ...], min_dim: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def adafactor_init(params: PyTree, cfg: OptimizerConfig) -> dict:
    def stat(p):
        if _factored(p.shape, cfg.min_dim_factored):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {
        "stats": jax.tree_util.tree_map(stat, params),
        "master": jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params),
    }


def adafactor_update(cfg: OptimizerConfig, grads, state, step):
    lr = schedule_fn(cfg)(step)
    t = (step + 1).astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_rate)

    def upd(g, st, master):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + 1e-30
        if "vr" in st:
            vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            v_est = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            v_est = v
            new_st = {"v": v}
        update = g * jax.lax.rsqrt(v_est + 1e-30)
        # update clipping (RMS <= 1), standard adafactor
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        if master.ndim >= 2:
            update = update + cfg.weight_decay * master
        master = master - lr * update
        return new_st, master

    is_stat = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    flat = jax.tree_util.tree_map(upd, grads, state["stats"],
                                  state["master"], is_leaf=None)
    stats = jax.tree_util.tree_map(lambda x: x[0], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree_util.tree_map(lambda x: x[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    _ = is_stat
    return {"stats": stats, "master": master}


# --------------------------------------------------------------------------
# Unified interface
# --------------------------------------------------------------------------
def init_opt_state(cfg: OptimizerConfig, params: PyTree) -> dict:
    if cfg.name == "adafactor":
        return adafactor_init(params, cfg)
    return adamw_init(params)


def apply_updates(cfg: OptimizerConfig, grads: PyTree, state: dict,
                  step: jax.Array) -> tuple[dict, dict]:
    """-> (new_state, metrics).  The fp32 master inside the state is the
    single source of truth; callers cast it to model dtypes."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    if cfg.name == "adafactor":
        new_state = adafactor_update(cfg, grads, state, step)
    else:
        new_state = adamw_update(cfg, grads, state, step)
    return new_state, {"grad_norm": gnorm, "lr": schedule_fn(cfg)(step)}
