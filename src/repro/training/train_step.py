"""Train step: grad accumulation (lax.scan over microbatches) + remat.

Mixed precision, master-only state: the optimizer's fp32 master copy is
the single source of truth (no separate bf16 param tree in the state — that
would alias fp32 leaves and break donation).  The step casts master ->
per-leaf model dtypes for the forward/backward; backprop runs in bf16 and
the cast's vjp yields fp32 per-param grads, which accumulate across
microbatches in ``accum_dtype`` (bf16 halves the accumulator footprint —
required for the 405B single-pod fit).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.models.params import ParamSpec
from repro.training.optimizer import (OptimizerConfig, apply_updates,
                                      init_opt_state)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    accum_steps: int = 1              # microbatches per step
    remat: str = "full"               # none | full | dots
    accum_dtype: str = "float32"      # float32 | bfloat16
    # int8 gradient compression with error feedback: models a compressed
    # cross-replica gradient exchange (per-tensor absmax scale, residual
    # carried in the state so quantization error re-enters the next step)
    grad_compression: str = "none"    # none | int8


def init_train_state(model: Model, rng: jax.Array,
                     cfg: TrainConfig) -> dict:
    params = model.init(rng)
    state = {
        "opt": init_opt_state(cfg.optimizer, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.grad_compression == "int8":
        state["ef"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def _compress_int8(g: jax.Array, residual: jax.Array
                   ) -> tuple[jax.Array, jax.Array]:
    """Error-feedback int8 quantization of one gradient tensor."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def cast_params(master: PyTree, specs: PyTree) -> PyTree:
    """fp32 master -> model-dtype params (bf16 weights, fp32 norms)."""
    return jax.tree_util.tree_map(
        lambda m, sp: m.astype(sp.dtype), master, specs)


def params_of(state: dict, model: Model) -> PyTree:
    return cast_params(state["opt"]["master"], model.param_specs())


def abstract_train_state(model: Model, cfg: TrainConfig) -> dict:
    """ShapeDtypeStruct state for the dry-run (no allocation)."""
    params = model.abstract()
    zeros = jax.eval_shape(
        lambda p: init_opt_state(cfg.optimizer, p), params)
    return {"opt": zeros,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _split_microbatches(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) on every leaf with a leading batch dim.

    M-RoPE positions carry a leading (3,) axis before batch — handled by
    splitting on axis 1 for rank-3 int32 'positions'."""
    def split(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name == "positions" and x.ndim == 3:
            return jnp.moveaxis(
                x.reshape(x.shape[0], n, x.shape[1] // n, x.shape[2]), 1, 0)
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])
    return jax.tree_util.tree_map_with_path(split, batch)


def make_train_step(model: Model, cfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""
    accum_dtype = jnp.dtype(cfg.accum_dtype)
    specs = model.param_specs()

    def loss_fn(master, mb):
        params = cast_params(master, specs)
        loss, parts = model.loss(params, mb, remat=cfg.remat)
        return loss, parts

    def step(state, batch):
        master = state["opt"]["master"]
        if cfg.accum_steps > 1:
            mbs = _split_microbatches(batch, cfg.accum_steps)

            def accum(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(master, mb)
                gacc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(accum_dtype), gacc, grads)
                return (gacc, lacc + loss), None

            gzero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), master)
            (gsum, lsum), _ = jax.lax.scan(
                accum, (gzero, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / cfg.accum_steps, gsum)
            loss = lsum / cfg.accum_steps
        else:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(master, batch)

        new_state = {}
        if cfg.grad_compression == "int8":
            pairs = jax.tree_util.tree_map(_compress_int8, grads,
                                           state["ef"])
            grads = jax.tree_util.tree_map(
                lambda t: t[0], pairs,
                is_leaf=lambda x: isinstance(x, tuple))
            new_state["ef"] = jax.tree_util.tree_map(
                lambda t: t[1], pairs,
                is_leaf=lambda x: isinstance(x, tuple))

        new_opt, om = apply_updates(cfg.optimizer, grads, state["opt"],
                                    state["step"])
        new_state.update(opt=new_opt, step=state["step"] + 1)
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return step
