from repro.training.optimizer import (OptimizerConfig, apply_updates,
                                      init_opt_state, schedule_fn)
from repro.training.train_step import (TrainConfig, init_train_state,
                                       make_train_step)

__all__ = ["OptimizerConfig", "apply_updates", "init_opt_state",
           "schedule_fn", "TrainConfig", "init_train_state",
           "make_train_step"]
