"""Host-side page-pool accounting for the paged KV cache.

The device holds one physical page pool per attention layer: every cache
leaf whose spec carries a ``"seq"`` axis is reshaped from a dense
``(batch, max_len, ...)`` row layout to ``(n_pages + 1, page_size, ...)``
pages, and a per-slot ``page_table`` of physical page indices rides
inside the cache pytree (so every compiled executable is keyed on the
page-table shape for free).  Physical page 0 is a pinned *trash* page:
free slots and unallocated table entries point at it, so masked or
frozen-row writes land somewhere harmless and gathers of it are causally
invisible behind ``kv_valid``.

This module is the host bookkeeping half: refcounts, the free list,
worst-case commitment accounting (`UnitPool` idiom — committed pages are
reserved but not yet allocated, so ``used + committed <= total`` means a
committed slot can never fail a later allocation), and the prefix-share
index that lets admissions deduplicate common prompt pages across
requests and tenants.

Prefix index keying is collision-free by construction: a published page
is keyed by the *entire* token chain from position 0 through its own
last token, not by a hash of it, so two different prompts can never
alias the same entry.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

TRASH_PAGE = 0


@dataclass
class PagePool:
    """Refcounted fixed-size page pool with commitment accounting.

    ``total`` counts usable pages; the trash page is physical index 0
    and is never allocated.  Invariants:

    - ``used_pages + free_pages == total``
    - ``used_pages + committed <= total`` (checked by :meth:`can_commit`),
      so every page drawn against a prior commitment is guaranteed.
    """

    total: int
    page_size: int
    committed: int = 0
    peak_used: int = 0
    requests: int = 0
    conflicts: int = 0      # admissions refused for page shortage
    shared_hits: int = 0    # pages deduplicated via the prefix index
    cow_copies: int = 0     # shared pages privatized before a write
    stalls: int = 0         # decode rows clamped waiting on a free page
    _free: list[int] = field(default_factory=list, repr=False)
    _ref: dict[int, int] = field(default_factory=dict, repr=False)
    # chain (tokens before this page) -> {page tokens -> physical page}
    _index: dict[tuple, dict[tuple, int]] = field(default_factory=dict,
                                                  repr=False)
    _published: dict[int, tuple[tuple, tuple]] = field(default_factory=dict,
                                                       repr=False)

    def __post_init__(self) -> None:
        if self.total < 1:
            raise ValueError("page pool needs at least one usable page")
        if self.page_size < 1:
            raise ValueError("page_size must be positive")
        # pop() hands out low physical indices first
        self._free = list(range(self.total, 0, -1))

    # -- capacity ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.total - len(self._free)

    @property
    def uncommitted_free(self) -> int:
        """Pages neither allocated nor promised to an admitted request."""
        return max(0, len(self._free) - self.committed)

    def pages_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.page_size)

    # -- commitment (UnitPool idiom) ---------------------------------------
    def can_commit(self, n: int) -> bool:
        return self.used_pages + self.committed + n <= self.total

    def commit(self, n: int) -> bool:
        """Reserve ``n`` future pages; counted as a conflict on refusal."""
        self.requests += 1
        if not self.can_commit(n):
            self.conflicts += 1
            return False
        self.committed += n
        return True

    def uncommit(self, n: int) -> None:
        if n > self.committed:
            raise ValueError(f"uncommit({n}) exceeds committed "
                             f"{self.committed}")
        self.committed -= n

    # -- allocation --------------------------------------------------------
    def alloc(self, *, reserved: bool) -> int | None:
        """Pop a free page (refcount 1).

        ``reserved=True`` draws against a prior :meth:`commit` (guaranteed
        to succeed); ``reserved=False`` only takes pages not promised to
        anyone else, returning ``None`` — a stall — when none remain.
        """
        if reserved:
            if self.committed < 1:
                raise RuntimeError("reserved alloc without commitment")
            self.committed -= 1
        elif len(self._free) <= self.committed:
            self.stalls += 1
            return None
        if not self._free:      # unreachable when invariants hold
            raise RuntimeError("page pool free list empty despite "
                               "commitment accounting")
        page = self._free.pop()
        self._ref[page] = 1
        self.peak_used = max(self.peak_used, self.used_pages)
        return page

    def retain(self, page: int) -> None:
        if page == TRASH_PAGE:
            return
        self._ref[page] += 1

    def release(self, page: int) -> bool:
        """Drop one reference; frees (and unpublishes) at zero."""
        if page == TRASH_PAGE:
            return False
        self._ref[page] -= 1
        if self._ref[page] > 0:
            return False
        del self._ref[page]
        self.unpublish(page)
        self._free.append(page)
        return True

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    # -- prefix-share index ------------------------------------------------
    def publish(self, chain: tuple, tokens: tuple, page: int) -> None:
        """Advertise ``page`` as holding KV for ``tokens`` after ``chain``."""
        if page == TRASH_PAGE or not tokens:
            return
        self._index.setdefault(chain, {})[tokens] = page
        self._published[page] = (chain, tokens)

    def unpublish(self, page: int) -> None:
        entry = self._published.pop(page, None)
        if entry is None:
            return
        chain, tokens = entry
        bucket = self._index.get(chain)
        if bucket is not None and bucket.get(tokens) == page:
            del bucket[tokens]
            if not bucket:
                del self._index[chain]

    def lookup(self, chain: tuple, tokens: tuple) -> int | None:
        """Exact full-page match: a published page holding ``tokens``."""
        return self._index.get(chain, {}).get(tokens)

    def lookup_covering(self, chain: tuple, prefix: tuple) -> int | None:
        """Partial-tail match: a published page after ``chain`` whose
        tokens *start with* ``prefix`` — i.e. it already holds correct KV
        for the borrower's entire remaining prompt (anything beyond is
        causally masked until the borrower overwrites it post-COW)."""
        if not prefix:
            return None
        n = len(prefix)
        for tokens, page in self._index.get(chain, {}).items():
            if len(tokens) >= n and tokens[:n] == prefix:
                return page
        return None

    @property
    def published_pages(self) -> int:
        return len(self._published)
