"""Discrete-event multi-tenant serving simulator.

Drives the *production* scheduler/compiler objects (repro.core.*) — only
time advancement is simulated; every scheduling, threshold, version and
allocation decision is the real code path.  Latencies come from the
analytical cost model charged with the true co-runner pressure at chunk
start (the scheduler itself only sees the proxy's estimate, like the real
system).

Two mechanisms mirror the paper's runtime exactly:

  * work-conserving grants — a chunk may start below its QoS-minimum
    allocation when the pool is tight;
  * grow-on-free upgrades — when units free up, under-allocated running
    chunks absorb them first and their finish time is recomputed; the
    respawn/re-shard overhead (Fig. 5b, ~220us on the CPU platform) is
    charged once per upgraded chunk.

Straggler mitigation: chunks may randomly run slow (node flakiness at pod
scale); a chunk exceeding ``straggler_factor`` x its prediction is
re-dispatched (bounded detection + redo cost).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools

import numpy as np

from repro.core import cost_model as cm
from repro.core.allocator import UnitPool
from repro.core.interference import RunningDemand, pressure_on
from repro.core.layer_block import ModelPlan
from repro.core.qos import QueryRecord, ServingMetrics, summarize
from repro.core.scheduler import Policy, TaskState


@dataclasses.dataclass
class SimConfig:
    max_sim_time: float = 1e9
    straggler_factor: float = 4.0     # x predicted latency => straggler
    straggler_prob: float = 0.0       # per-chunk chance of running slow
    straggler_slowdown: float = 5.0
    seed: int = 0


@dataclasses.dataclass
class RunningChunk:
    task: TaskState
    versions: list
    itf: cm.Interference
    units: int                 # currently held
    units_min: int             # QoS requirement (upgrade target)
    start: float
    finish: float
    demand: RunningDemand
    epoch: int = 0             # bumps on upgrade; stale events are dropped
    upgraded: bool = False

    def lat_at(self, hw, units: int) -> float:
        return sum(cm.latency(hw, v, units, self.itf) for v in self.versions)


class Simulator:
    def __init__(self, hw: cm.HardwareSpec, plans: dict[str, ModelPlan],
                 policy: Policy, sim_cfg: SimConfig | None = None):
        self.hw = hw
        self.plans = plans
        self.policy = policy
        self.cfg = sim_cfg or SimConfig()
        self.rng = np.random.default_rng(self.cfg.seed)

        self.pool = UnitPool(hw.n_units)
        self.demands: list[RunningDemand] = []
        self.pending: list[TaskState] = []
        self.active: list[TaskState] = []
        self.running: list[RunningChunk] = []
        self.records: list[QueryRecord] = []
        self.busy_unit_time = 0.0
        self.alloc_unit_time = 0.0
        self.requests = 0
        self.conflicts = 0
        self.stragglers = 0
        self._seq = itertools.count()
        self._conflict_marker: dict[int, int] = {}

    # ------------------------------------------------------------------
    def run(self, workload: list[tuple[float, str]]) -> ServingMetrics:
        events: list[tuple[float, int, str, object]] = []
        for t, name in workload:
            heapq.heappush(events, (t, next(self._seq), "arrival", name))
        qps = len(workload) / max(workload[-1][0], 1e-9) if workload \
            else 0.0
        tid = itertools.count()

        while events:
            now, _, kind, payload = heapq.heappop(events)
            if now > self.cfg.max_sim_time:
                # truncated run: chunks still in flight never reach
                # _on_finish, so account their held unit-time here or
                # unit_efficiency overstates
                self._flush_inflight()
                break
            if kind == "arrival":
                task = TaskState(tid=next(tid), tenant=payload,
                                 plan=self.plans[payload], arrival=now)
                self.active.append(task)
                if not self._try_start(task, now, events):
                    self.pending.append(task)
            elif kind == "finish":
                chunk, epoch = payload
                if chunk.epoch != epoch:
                    continue                      # stale (chunk upgraded)
                self._on_finish(chunk, now, events)
        return summarize(self.records, qps,
                         self.conflicts / max(self.requests, 1),
                         self.busy_unit_time, self.alloc_unit_time)

    def _flush_inflight(self) -> None:
        """Charge allocated unit-time of still-running chunks at
        termination — the full start..finish hold _on_finish would have
        charged (their busy flops were already charged in full at start,
        so clipping alloc at the cut-off would still overstate
        efficiency)."""
        for chunk in self.running:
            self.alloc_unit_time += chunk.units * (chunk.finish
                                                   - chunk.start)

    # ------------------------------------------------------------------
    def _on_finish(self, chunk: RunningChunk, now, events):
        task = chunk.task
        self.pool.release(chunk.units)
        self.alloc_unit_time += chunk.units * (now - chunk.start)
        self.running.remove(chunk)
        if chunk.demand in self.demands:
            self.demands.remove(chunk.demand)
        if task.done:
            self.active.remove(task)
            self.records.append(QueryRecord(
                tenant=task.tenant, arrival=task.arrival, finish=now,
                qos_s=task.plan.qos_s))
        else:
            # Alg. 3 worker: a task's next block launches back-to-back on
            # the cores it just released — no yield to the queue.
            if not self._try_start(task, now, events):
                self.pending.append(task)
        self._grow_running(now, events)           # paper: grow-on-free next
        self._dispatch(now, events)

    def _grow_running(self, now, events):
        """Give freed units to under-allocated running chunks (oldest
        first) and pull their finish times in."""
        for chunk in sorted(self.running, key=lambda c: c.start):
            if self.pool.free <= 0:
                return
            if chunk.units >= chunk.units_min:
                continue
            extra = min(chunk.units_min - chunk.units, self.pool.free)
            got = self.pool.try_alloc(extra)
            if got <= 0:
                continue
            frac_left = max(chunk.finish - now, 0.0) / max(
                chunk.finish - chunk.start, 1e-12)
            self.alloc_unit_time += chunk.units * (now - chunk.start)
            new_units = chunk.units + got
            new_total = chunk.lat_at(self.hw, new_units)
            remaining = frac_left * new_total
            if not chunk.upgraded:
                remaining += self.hw.realloc_overhead_s
                chunk.upgraded = True
            chunk.units = new_units
            chunk.start = now
            chunk.finish = now + remaining
            chunk.epoch += 1
            heapq.heappush(events, (chunk.finish, next(self._seq), "finish",
                                    (chunk, chunk.epoch)))

    def _dispatch(self, now, events):
        if self.pool.free <= 0:
            return
        order = self.policy.order_pending(self.pending, now)
        started = []
        for task in order:
            if self.pool.free <= 0:
                break
            if self._try_start(task, now, events):
                started.append(task)
            elif self.policy.strict_fcfs:
                break
        for task in started:
            self.pending.remove(task)

    def _try_start(self, task: TaskState, now: float, events) -> bool:
        plan = self.policy.plan_chunk(task, self.active, self.demands, now,
                                      self.pool.free)
        if plan is None:
            return False
        units_req = max(1, min(plan.units, self.hw.n_units))
        units_min = max(1, min(plan.units_min, units_req))
        first_attempt = self._conflict_marker.get(task.tid) != task.next_layer
        if first_attempt:
            self.requests += 1
            self._conflict_marker[task.tid] = task.next_layer

        if plan.exclusive and self.pool.used > 0:
            return False
        if not plan.allow_partial:
            if self.pool.free < units_req:
                if first_attempt:
                    self.conflicts += 1
                return False
            grant = units_req
        else:
            # work-conserving: start on whatever is free; grow-on-free will
            # top it up to units_min (conflict = started below the minimum)
            if self.pool.free <= 0:
                if first_attempt:
                    self.conflicts += 1
                return False
            grant = min(units_req, self.pool.free)
            if grant < units_min and first_attempt:
                self.conflicts += 1
        got = self.pool.try_alloc(grant)
        assert got == grant

        itf = pressure_on(task.tid, self.demands, now)
        lat = sum(cm.latency(self.hw, v, grant, itf) for v in plan.versions)
        if self.cfg.straggler_prob and \
                self.rng.random() < self.cfg.straggler_prob:
            slow = lat * self.cfg.straggler_slowdown
            if slow > self.cfg.straggler_factor * lat:
                # straggler: detected at the deadline factor, re-dispatched
                self.stragglers += 1
                lat = self.cfg.straggler_factor * lat + lat
            else:
                lat = slow

        bw = sum(cm.bw_demand(self.hw, v, grant, itf)
                 for v in plan.versions) / len(plan.versions)
        cache = sum(cm.cache_demand(self.hw, v, grant)
                    for v in plan.versions) / len(plan.versions)
        ici = sum(cm.ici_demand(self.hw, v, grant, itf)
                  for v in plan.versions) / len(plan.versions)
        demand = RunningDemand(tenant=task.tid, bw=bw, cache=cache, ici=ici,
                               start=now, finish=now + lat)
        self.demands.append(demand)
        self.busy_unit_time += sum(
            v.flops / self.hw.flops_per_unit for v in plan.versions)
        task.next_layer = plan.end_layer
        chunk = RunningChunk(task=task, versions=plan.versions, itf=itf,
                             units=grant, units_min=units_min, start=now,
                             finish=now + lat, demand=demand)
        self.running.append(chunk)
        heapq.heappush(events, (chunk.finish, next(self._seq), "finish",
                                (chunk, chunk.epoch)))
        return True


def run_sweep(hw, plans, policy_fn, workload_fn, qps_list,
              sim_cfg: SimConfig | None = None):
    """[(qps, metrics)] for a QPS sweep — input to qos.qps_at_qos."""
    out = []
    for qps in qps_list:
        sim = Simulator(hw, plans, policy_fn(), sim_cfg)
        out.append((qps, sim.run(workload_fn(qps))))
    return out
