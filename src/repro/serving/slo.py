"""SLO-tiered quantum scheduling and slack-aware admission control.

VELTAIR's headline metric is queries served *under a QoS target*
(paper §6), and PREMA's latency-tier scheduling is the model: every
schedulable unit — a prefill chunk or a fused decode quantum — carries
a deadline-derived urgency, and the runtime picks the next quantum by
earliest deadline instead of FIFO alternation.  Three pieces live here,
shared by ``OnlineRuntime`` and ``ClusterRuntime``:

* :class:`DeadlineBook` — per-request deadline bookkeeping.  A request's
  tier (``interactive``/``standard``/``batch``) scales its tenant's base
  QoS target into an absolute finish deadline and a TTFT sub-deadline
  (core.qos.TierSpec).
* :func:`pick_quantum` — the earliest-deadline pick over the engine's
  prefill queue and decode backlog, with a shortest-remaining-work
  tie-break (pure least-slack degenerates to round-robin on equal
  deadlines, and SRPT is the finisher: it retires queries, which is
  what qps_at_qos counts).  TTFT-urgent prefill chunks preempt decode
  quanta; batch-tier decodes yield; a decode quantum's length is capped
  by the tightest pending TTFT deadline so an urgent admission is never
  stuck behind a 16-step fused block.
* :class:`AdmissionController` — sheds or defers load *before* QoS
  collapses: a sheddable-tier request whose estimated finish already
  overruns its deadline at admission time is rejected (counted, never
  silently dropped); batch-tier and engine-full admissions defer.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.qos import DEFAULT_TIERS, TierSpec, tier_spec


@dataclasses.dataclass(frozen=True)
class SloEntry:
    """Deadline state for one in-flight request."""
    rid: int
    tenant: str
    tier: str | None            # None = untiered legacy request
    arrival: float
    qos_s: float
    deadline: float             # absolute finish deadline (ordering; only
                                # written to the QueryRecord when tiered)
    ttft_deadline: float        # absolute first-token deadline

    def slack(self, now: float) -> float:
        return self.deadline - now


class DeadlineBook:
    """rid -> :class:`SloEntry` map both runtimes order quanta by.

    Untiered requests (``tier=None``) get *standard*-tier deadlines for
    ordering purposes only — their QueryRecords keep the legacy
    ``latency <= qos_s`` satisfaction semantics."""

    def __init__(self, tiers: dict[str, TierSpec] | None = None):
        self.tiers = tiers or DEFAULT_TIERS
        self._entries: dict[int, SloEntry] = {}

    def register(self, rid: int, tenant: str, tier: str | None,
                 arrival: float, qos_s: float) -> SloEntry:
        spec = tier_spec(tier, self.tiers)
        deadline = arrival + spec.deadline_scale * qos_s
        e = SloEntry(rid=rid, tenant=tenant, tier=tier, arrival=arrival,
                     qos_s=qos_s, deadline=deadline,
                     ttft_deadline=arrival + spec.ttft_frac
                     * spec.deadline_scale * qos_s)
        self._entries[rid] = e
        return e

    def entry(self, rid: int) -> SloEntry:
        return self._entries[rid]

    def get(self, rid: int) -> SloEntry | None:
        return self._entries.get(rid)

    def drop(self, rid: int) -> None:
        self._entries.pop(rid, None)

    def spec(self, tier: str | None) -> TierSpec:
        return tier_spec(tier, self.tiers)


def pick_quantum(engine, book: DeadlineBook, now: float, step_dt: float,
                 k_max: int) -> tuple[str, int] | None:
    """Earliest-deadline pick over one engine's schedulable units.

    Returns ``("prefill", slot)`` — run that slot's next chunk — or
    ``("decode", k)`` — run a fused decode quantum of ``k`` steps — or
    ``None`` when the engine is idle.  Ordering keys:

    * prefill chunk for slot s:  (TTFT deadline, chunks left, s)
    * decode quantum:            (earliest finish deadline among
                                  decodable rows, tokens left, s)

    A decode pick's ``k`` is clamped so the quantum ends before the
    tightest *pending* TTFT deadline — urgency preempts at the quantum
    boundary, never mid-executable (token streams stay exact)."""
    prefill = engine.prefill_queue()
    decode = engine.decode_backlog()
    if not prefill and not decode:
        return None

    def pkey(item):
        slot, rid, chunks_left = item
        e = book.get(rid)
        dl = e.ttft_deadline if e is not None else math.inf
        return (dl, chunks_left, slot)

    def dkey(item):
        slot, rid, toks_left = item
        e = book.get(rid)
        dl = e.deadline if e is not None else math.inf
        return (dl, toks_left, slot)

    # memory is a scheduling dimension on paged engines: a quantum longer
    # than the free-page headroom would stall rows mid-quantum, so clamp
    # k up front (dense engines pass k through unchanged)
    headroom = getattr(engine, "decode_k_headroom", None)
    k_mem = headroom(k_max) if callable(headroom) else k_max
    if not decode:
        return ("prefill", min(prefill, key=pkey)[0])
    if not prefill:
        return ("decode", k_mem)
    best_p = min(prefill, key=pkey)
    best_d = min(decode, key=dkey)
    p_dl = pkey(best_p)[0]
    if p_dl <= dkey(best_d)[0]:
        return ("prefill", best_p[0])
    # decode wins now, but end the quantum before the tightest pending
    # TTFT deadline comes due (each chunk/step costs ~step_dt).  On a
    # speculative engine a "step" emits ~expected_accept tokens (the
    # engine's acceptance EWMA), so the same wall slack buys a deeper
    # token quantum — without this the scheduler would under-fill spec
    # quanta exactly when drafts are landing
    slack_steps = int((p_dl - now) / step_dt) - best_p[2]
    tpq = getattr(engine, "expected_accept_per_step", None)
    if callable(tpq):
        slack_steps = int(slack_steps * max(1.0, float(tpq())))
    return ("decode", max(1, min(k_mem, slack_steps)))


@dataclasses.dataclass
class AdmissionController:
    """Slack-aware admission: shed hopeless sheddable-tier requests and
    defer the rest, *before* they drag every co-resident query past its
    deadline.

    The finish estimate is deliberately coarse — serial backlog chunks
    plus the request's own prefill chunks and decode steps, each costing
    ~``step_dt`` — because admission only has to be right about
    *hopeless* requests (estimated finish already past the deadline with
    ``headroom`` slack).  Batch tier is never shed (``sheddable=False``):
    it defers until a slot frees up."""
    headroom: float = 1.0       # shed when est_finish > arrival-relative
                                # deadline stretched by this factor

    def decide(self, *, now: float, entry: SloEntry, spec: TierSpec,
               step_dt: float, own_chunks: int, own_decode_steps: int,
               backlog_chunks: int, slot_free: bool, pages_needed: int = 0,
               pages_free: int | None = None) -> str:
        """One of ``"admit"`` / ``"defer"`` / ``"shed"``.

        ``pages_needed`` / ``pages_free`` make memory an admission
        dimension on paged engines: a request whose worst-case page
        commitment (net of shareable prefix pages) exceeds the pool's
        uncommitted surplus defers — occupancy-slot *and* page-pool
        exhaustion are both counted, never silent.  Dense engines pass
        ``pages_free=None`` (no page gate)."""
        if not slot_free:
            return "defer"
        if pages_free is not None and pages_needed > pages_free:
            return "defer"
        est_steps = backlog_chunks + own_chunks + own_decode_steps
        est_finish = now + est_steps * step_dt
        budget = entry.arrival + self.headroom * (entry.deadline
                                                  - entry.arrival)
        if spec.sheddable and est_finish > budget:
            return "shed"
        return "admit"
