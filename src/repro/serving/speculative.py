"""Prompt-lookup (n-gram) drafter for speculative decode quanta.

No second model: drafts come from the request's own token history
(prompt + generated output), the "prompt lookup decoding" trick — find
the most recent earlier occurrence of the trailing n-gram and propose
the tokens that followed it.  Pure host-side numpy over tokens the
engine already tracks, so drafting adds no device syncs and no compiled
executables; the device only ever sees the fixed-shape (B, d) draft
block fed to ``Model.verify_quantum``.

Hit rate is workload-dependent by construction: repetitive text
(templated output, code, retrieval-stuffed prompts) drafts well; random
text drafts nothing — the engine falls back to the plain fused quantum
when no row has a usable draft, so an adversarial workload costs only
the (cheap) failed lookup.
"""
from __future__ import annotations

import numpy as np


class NgramDrafter:
    """Drafts up to ``depth`` tokens by longest-suffix prompt lookup.

    For n from ``max_ngram`` down to ``min_ngram``, search the history
    (latest occurrence first) for the trailing n-gram; on a hit, propose
    the ``depth`` tokens that followed it (right-padded by repeating the
    last candidate when the hit sits near the end of history).
    """

    def __init__(self, depth: int = 4, max_ngram: int = 3,
                 min_ngram: int = 1):
        if depth < 1:
            raise ValueError("draft depth must be >= 1")
        if min_ngram < 1 or max_ngram < min_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.depth = int(depth)
        self.max_ngram = int(max_ngram)
        self.min_ngram = int(min_ngram)

    def draft(self, history, depth: int | None = None) -> np.ndarray | None:
        """history: 1-D int sequence (prompt + output so far, last entry
        = the token about to be fed to decode).  Returns (depth,) int32
        draft or None when no n-gram recurs."""
        hist = np.asarray(history, np.int32).reshape(-1)
        d = self.depth if depth is None else int(depth)
        n_hist = hist.shape[0]
        for n in range(min(self.max_ngram, n_hist - 1),
                       self.min_ngram - 1, -1):
            suffix = hist[n_hist - n:]
            # windows[i] = hist[i:i+n] over hist[:-1], so a hit at i has a
            # continuation starting at i+n that is inside the history
            windows = np.lib.stride_tricks.sliding_window_view(
                hist[:-1], n)
            hits = np.flatnonzero((windows == suffix).all(axis=1))
            if hits.size:
                j = int(hits[-1]) + n
                cand = hist[j:j + d]
                if cand.shape[0] < d:
                    cand = np.concatenate(
                        [cand,
                         np.full(d - cand.shape[0], cand[-1], np.int32)])
                return cand.astype(np.int32)
        return None
