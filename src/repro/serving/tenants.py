"""Tenant setup: compile paper-suite / LM-arch models into ModelPlans.

A *tenant* is a model with a QoS target; its :class:`ModelPlan` is the
compile-time artifact every scheduling policy works from (per-layer
version tables, QoS slices, ``Avg_C``).  Three builders cover the three
serving paths:

* :func:`build_paper_plans` — the paper's MLPerf CNN suite (simulator
  and single-engine online runtime);
* :func:`lm_serving_plans` — LM architectures on the TPU-pod hardware
  (analytic pod-scale scenarios);
* :func:`cluster_plan` — LM architectures on *either* platform with an
  auto-derived feasible QoS, used by ``repro.serving.cluster`` to
  co-locate heterogeneous real engines on one unit pool.
"""
from __future__ import annotations

import functools

from repro.configs import get_config, get_shape
from repro.configs.base import ShapeConfig
from repro.configs.paper_suite import paper_models
from repro.core import cost_model as cm
from repro.core.layer_block import ModelPlan, make_model_plan
from repro.core.multiversion import compile_model
from repro.core.profiles import lm_layers


@functools.lru_cache(maxsize=None)
def paper_plan(name: str, hw_name: str = "cpu") -> ModelPlan:
    hw = cm.CPU_3990X if hw_name == "cpu" else cm.TPU_V5E_POD
    pm = paper_models()[name]
    layers = list(pm.layers)
    qos_s = pm.qos_ms * 1e-3
    vsets = compile_model(layers, hw, qos_s)
    return make_model_plan(name, layers, vsets, qos_s, hw)


def build_paper_plans(names, hw: cm.HardwareSpec) -> dict[str, ModelPlan]:
    key = "cpu" if hw.cache_shared else "tpu"
    return {n: paper_plan(n, key) for n in names}


@functools.lru_cache(maxsize=None)
def lm_plan(arch: str, shape_name: str, qos_ms: float) -> ModelPlan:
    """LM tenant on the TPU pod (serving shapes; decode/prefill)."""
    hw = cm.TPU_V5E_POD
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    layers = lm_layers(cfg, shape)
    qos_s = qos_ms * 1e-3
    vsets = compile_model(layers, hw, qos_s)
    return make_model_plan(f"{arch}:{shape_name}", layers, vsets, qos_s, hw)


def lm_serving_plans(specs: list[tuple[str, str, float]],
                     ) -> dict[str, ModelPlan]:
    """specs: [(arch, shape_name, qos_ms)] -> plans keyed arch:shape."""
    return {f"{a}:{s}": lm_plan(a, s, q) for a, s, q in specs}


@functools.lru_cache(maxsize=None)
def cluster_plan(arch: str, hw: cm.HardwareSpec = cm.CPU_3990X, *,
                 qos_scale: float = 3.0,
                 shape_name: str = "decode_32k") -> ModelPlan:
    """Analytic ModelPlan for one co-located LM engine tenant, compiled
    for exactly the hardware the cluster will partition (``hw`` is a
    frozen dataclass, so memoization keys on the actual spec).

    Unlike :func:`lm_plan` this works on any platform and derives a
    *feasible* QoS instead of taking one: the versions are compiled
    first, then ``qos_s = qos_scale x`` the model's solo full-machine
    latency — so heterogeneous models (gemma_2b next to mamba2_780m)
    all get proportionate targets and the co-location comparison measures
    scheduling quality, not QoS mis-calibration."""
    cfg = get_config(arch)
    layers = lm_layers(cfg, get_shape(shape_name))
    vsets = compile_model(layers, hw)
    solo = sum(cm.latency(hw, vs.solo_version(), hw.n_units,
                          cm.Interference()) for vs in vsets)
    return make_model_plan(arch, layers, vsets, qos_scale * solo, hw)


def cluster_plans(archs: list[str], hw: cm.HardwareSpec, *,
                  qos_scale: float = 3.0) -> dict[str, ModelPlan]:
    """archs -> plans keyed by arch name (repro.serving.cluster input)."""
    return {a: cluster_plan(a, hw, qos_scale=qos_scale) for a in archs}


def engine_version_sets(plans: dict[str, ModelPlan]) -> list:
    """Flatten a tenant mix's multi-version tables for the online engine:
    ServingEngine picks its tile source (the dominant layer) from these,
    so level switches install versions the adaptive compiler produced."""
    return [vs for plan in plans.values() for vs in plan.version_sets]
