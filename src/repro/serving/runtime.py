"""Online multi-tenant serving runtime: the VELTAIR policy in the loop
of the real JAX execution path.

The discrete-event simulator (serving.simulator) exercises the decision
layer against an analytical cost model; this module closes the loop on
the *real* engine: per-tenant request queues feed a shared
:class:`~repro.serving.engine.ServingEngine`, and at every engine step
the runtime polls the (synthesized) performance counters for the live
slot occupancy and asks the scheduling policy to map them to an
interference level — counters through the calibrated
:class:`~repro.core.interference.LinearProxy`, never the oracle demand
sums — and the engine swaps to the matching code version via
``set_interference_level`` (kernel tile overrides,
repro.kernels.dispatch).  For N co-located engines with *different*
models sharing one unit pool, see :class:`repro.serving.cluster.ClusterRuntime`.

A :class:`Workload` is the shared currency: the same (arrival, tenant)
stream replays through both the simulator (``replay_through_simulator``)
and the engine (``OnlineRuntime.serve``), producing directly comparable
``ServingMetrics`` (core.qos.compare_metrics).

Time: the runtime advances a virtual clock by ``step_dt`` per engine
step (deterministic, hardware-independent — latency numbers are in
workload time, not wall time).  ``wall_clock=True`` instead charges the
measured wall time of each step — *including* the version switch that
precedes it, so any re-jit/compile stall shows up in latency (that's the
overhead VELTAIR's adaptive compilation amortizes; ``compile_time_s``
tracks it separately, and ``ServingEngine.warmup()`` eliminates it).
"""
from __future__ import annotations

import collections
import dataclasses
import time

from repro.core import cost_model as cm
from repro.core.interference import RunningDemand, read_counters
from repro.core.layer_block import ModelPlan
from repro.core.qos import QueryRecord, ServingMetrics, TierSpec, summarize
from repro.core.scheduler import Policy, TaskState
from repro.serving.engine import Request, ServingEngine
from repro.serving.request import (diurnal_workload, gamma_poisson_workload,
                                   poisson_workload, synth_prompts)
from repro.serving.simulator import SimConfig, Simulator
from repro.serving.slo import AdmissionController, DeadlineBook, pick_quantum


@dataclasses.dataclass
class Workload:
    """A replayable tenant mix: arrivals in virtual seconds plus the
    request shapes.  Prompts need not be aligned — the engine decodes
    every slot at its own position — so ``prompt_len_spread`` > 0 draws each
    query's length uniformly from [prompt_len - spread, prompt_len]
    (deterministic per seed)."""
    arrivals: list[tuple[float, str]]      # (time, tenant) sorted by time
    prompt_len: int = 8
    max_new_tokens: int = 4
    seed: int = 0
    prompt_len_spread: int = 0             # mixed-length prompts when > 0
    tiers: dict[str, str] | None = None    # tenant -> SLO tier name; None =
                                           # untiered legacy workload
    shared_prefix_len: int = 0             # every prompt opens with the same
                                           # shared_prefix_len tokens (system-
                                           # prompt traffic: the paged engine's
                                           # prefix index deduplicates them)

    @property
    def n_queries(self) -> int:
        return len(self.arrivals)

    def tier_of(self, tenant: str) -> str | None:
        """The tenant's SLO tier, or None for untiered workloads (legacy
        qos_s-relative satisfaction, standard-tier urgency)."""
        if self.tiers is None:
            return None
        return self.tiers.get(tenant)

    def prompt_lengths(self) -> list[int]:
        """Per-query prompt lengths (deterministic per seed)."""
        import numpy as np
        if not self.prompt_len_spread:
            return [self.prompt_len] * self.n_queries
        rng = np.random.default_rng(self.seed + 0x5EED)
        lo = max(1, self.prompt_len - self.prompt_len_spread)
        return [int(x) for x in
                rng.integers(lo, self.prompt_len + 1, self.n_queries)]

    @property
    def qps(self) -> float:
        if not self.arrivals:
            return 0.0
        return len(self.arrivals) / max(self.arrivals[-1][0], 1e-9)

    @staticmethod
    def poisson(tenants: list[str], qps: float, n_queries: int, *,
                prompt_len: int = 8, max_new_tokens: int = 4, seed: int = 0,
                weights: list[float] | None = None,
                prompt_len_spread: int = 0,
                shared_prefix_len: int = 0) -> "Workload":
        arr = poisson_workload(tenants, qps, n_queries, seed=seed,
                               weights=weights)
        return Workload(arr, prompt_len=prompt_len,
                        max_new_tokens=max_new_tokens, seed=seed,
                        prompt_len_spread=prompt_len_spread,
                        shared_prefix_len=shared_prefix_len)

    @staticmethod
    def bursty(tenants: list[str], qps: float, n_queries: int, *,
               burstiness: float = 4.0, interval_s: float = 0.05,
               prompt_len: int = 8, max_new_tokens: int = 4, seed: int = 0,
               weights: list[float] | None = None,
               prompt_len_spread: int = 0,
               shared_prefix_len: int = 0,
               tiers: dict[str, str] | None = None) -> "Workload":
        """Gamma-modulated Poisson arrivals (flash crowds at mean ``qps``
        offered load) — the heavy-traffic regime the paper targets."""
        arr = gamma_poisson_workload(tenants, qps, n_queries,
                                     burstiness=burstiness,
                                     interval_s=interval_s, seed=seed,
                                     weights=weights)
        return Workload(arr, prompt_len=prompt_len,
                        max_new_tokens=max_new_tokens, seed=seed,
                        prompt_len_spread=prompt_len_spread,
                        shared_prefix_len=shared_prefix_len, tiers=tiers)

    @staticmethod
    def diurnal(tenants: list[str], qps_peak: float, n_queries: int, *,
                period_s: float = 1.0, floor: float = 0.2,
                prompt_len: int = 8, max_new_tokens: int = 4, seed: int = 0,
                weights: list[float] | None = None,
                prompt_len_spread: int = 0,
                shared_prefix_len: int = 0,
                tiers: dict[str, str] | None = None) -> "Workload":
        """Sinusoidally-modulated arrivals (compressed diurnal cycle)."""
        arr = diurnal_workload(tenants, qps_peak, n_queries,
                               period_s=period_s, floor=floor, seed=seed,
                               weights=weights)
        return Workload(arr, prompt_len=prompt_len,
                        max_new_tokens=max_new_tokens, seed=seed,
                        prompt_len_spread=prompt_len_spread,
                        shared_prefix_len=shared_prefix_len, tiers=tiers)

    @staticmethod
    def replay(arrivals: list[tuple[float, str]], **kw) -> "Workload":
        """Trace replay: a recorded (time, tenant) stream — sorted here so
        captured traces need no preprocessing — with the request shapes
        supplied as keywords (scales to thousands of requests)."""
        return Workload(sorted(arrivals), **kw)


def replay_through_simulator(wl: Workload, hw: cm.HardwareSpec,
                             plans: dict[str, ModelPlan], policy: Policy,
                             sim_cfg: SimConfig | None = None
                             ) -> ServingMetrics:
    """The analytical side of the side-by-side comparison."""
    return Simulator(hw, plans, policy, sim_cfg).run(list(wl.arrivals))


def plan_demand(plan: ModelPlan, hw: cm.HardwareSpec,
                units: int) -> tuple[float, float, float]:
    """Mean per-layer (bw, cache, ici) demand of a tenant's solo versions
    at ``units`` — the analytical footprint one active engine slot
    imposes on its co-runners."""
    vs = [s.solo_version() for s in plan.version_sets]
    itf0 = cm.Interference()
    n = len(vs) or 1
    bw = sum(cm.bw_demand(hw, v, units, itf0) for v in vs) / n
    cache = sum(cm.cache_demand(hw, v, units) for v in vs) / n
    ici = sum(cm.ici_demand(hw, v, units, itf0) for v in vs) / n
    return bw, cache, ici


class OnlineRuntime:
    """Admission/dispatch loop over a real ServingEngine.

    Each iteration: admit due arrivals into free slots, derive the live
    interference level from the policy, apply it to the engine's kernel
    dispatch, dispatch the next layer-block-sized quantum as ONE fused
    on-device call (``fused=True``, the default) or a single batched
    decode step (``fused=False``, the per-step baseline), and record
    completions as QueryRecords against each tenant's QoS deadline.

    In fused mode the policy's layer-block plan (``plan_chunk_at``)
    sets the dispatch quantum: the scheduler only intervenes at block
    boundaries, and the engine syncs the host exactly once per quantum
    (``engine.host_syncs`` / ``engine.tokens_per_sync`` measure it).
    Completions inside a quantum keep exact virtual finish times — the
    engine reports per-request executed steps.

    Admission is metered: a prompt is admitted as a queue of prefill
    *chunks* (``engine.admit_request`` + ``engine.prefill_step``), and
    each chunk is one scheduled quantum — it passes through the same
    counter poll / level switch as a decode quantum, advances the
    virtual clock, and is charged to ``busy``/``alloc``.  Prefill and
    decode quanta strictly alternate while both have work, so a long
    prompt stalls co-resident decodes for at most one chunk, and TTFT
    (``QueryRecord.ttft_s`` / ``ServingMetrics.avg_ttft_s``) is real
    virtual time, not zero.  Inadmissible prompts (``len >= max_len``)
    are rejected at admission and counted as conflicts.

    Scheduling (``scheduler=``): ``"slo"`` (default) picks every quantum
    by earliest deadline over the prefill queue and decode backlog
    (serving.slo.pick_quantum) — TTFT-urgent prefill chunks preempt
    decode quanta, batch-tier decodes yield — and admissions go in
    earliest-deadline order through the optional
    :class:`~repro.serving.slo.AdmissionController` (shed/defer counted
    in ``ServingMetrics.shed_queries``/``deferred_queries``).  ``"fifo"``
    keeps the legacy strict prefill/decode alternation and
    arrival-order admission.  Both orderings retire every request with
    identical per-request token streams — scheduling reorders quanta,
    never changes what a row computes."""

    def __init__(self, engine: ServingEngine, policy: Policy,
                 plans: dict[str, ModelPlan], hw: cm.HardwareSpec, *,
                 step_dt: float = 1e-3, wall_clock: bool = False,
                 max_steps: int = 200_000, seed: int = 0,
                 fused: bool = True, scheduler: str = "slo",
                 admission: AdmissionController | None = None,
                 tiers: dict[str, TierSpec] | None = None,
                 counter_source: str = "oracle",
                 refit_proxy: bool | None = None):
        if scheduler not in ("slo", "fifo"):
            raise ValueError(f"scheduler must be 'slo' or 'fifo', "
                             f"got {scheduler!r}")
        if counter_source not in ("oracle", "measured"):
            raise ValueError(f"counter_source must be 'oracle' or "
                             f"'measured', got {counter_source!r}")
        self.engine = engine
        self.policy = policy
        self.plans = plans
        self.hw = hw
        self.step_dt = step_dt
        self.wall_clock = wall_clock
        self.max_steps = max_steps
        self.fused = fused
        self.scheduler = scheduler
        self.admission = admission       # None = admit everything (legacy)
        self.book = DeadlineBook(tiers)
        # counter provenance: "oracle" synthesizes samples from the demand
        # sums (legacy, deterministic per seed); "measured" derives them
        # from the engine's per-quantum wall-time bank, falling back to
        # oracle while the bank is cold.  refit_proxy=None enables the
        # online RLS re-fit exactly when serving on measured counters.
        self.counter_source = counter_source
        self.refit_proxy = (counter_source == "measured"
                            if refit_proxy is None else bool(refit_proxy))
        self.counter_sources = collections.Counter()  # source label -> polls
        import numpy as np
        self._rng = np.random.default_rng(seed)   # counter-read noise
        self.records: list[QueryRecord] = []
        self.level_trace: list[float] = []
        self.sched_trace: list[tuple] = []  # ("prefill", rid, tier, t) |
                                            # ("decode", (rids...), t)
        self.outputs: dict[int, list[int]] = {}  # rid -> served tokens
        self.conflicts = 0
        self.shed = 0                    # rejected by admission control
        self.deferred = 0                # admissions delayed past arrival
        self.steps = 0
        self.quanta = 0                  # decode dispatch quanta issued
        self.prefill_quanta = 0          # prefill-chunk quanta issued
        self._prefill_last = False       # prefill/decode alternation state
        self._ttft: dict[int, float] = {}   # rid -> time to first token
        self._cursor = 0                 # layer-block cursor (fused mode)
        self._cursor_n = 1               # cursor modulus (head plan layers)
        # wall time spent inside set_interference_level — with a warmed
        # version cache this is pure dictionary swaps; without it, this is
        # where re-jit/compile stalls land (and they ARE charged to latency
        # in wall_clock mode: the step timer starts before the switch)
        self.compile_time_s = 0.0
        # analytical per-tenant footprint at the fair-share allocation
        units = max(1, hw.n_units // max(engine.slots, 1))
        self._demand = {name: plan_demand(plan, hw, units)
                        for name, plan in plans.items()}

    # ------------------------------------------------------------------
    @property
    def host_syncs(self) -> int:
        return self.engine.host_syncs

    @property
    def tokens_per_sync(self) -> float:
        return self.engine.tokens_per_sync

    def _plan_quantum(self, meta: dict, sample, now: float) -> int:
        """Dispatch-quantum length from the policy's layer-block plan:
        the head-of-line tenant's next block at the proxied pressure
        (Alg. 2/3) — block size == decode steps until the scheduler
        intervenes again.  Static policies yield their natural quanta
        (model-wise: a whole pass; fixed-block: K; layer-wise: 1)."""
        head = None
        for req in self.engine.slot_req:
            if req is None:
                continue
            tenant, _, admit = meta[req.rid]
            if head is None or admit < head[1]:
                head = (tenant, admit)
        if head is None:
            return 1
        plan = self.plans[head[0]]
        task = TaskState(tid=0, tenant=head[0], plan=plan,
                         arrival=head[1],
                         next_layer=self._cursor % plan.n_layers)
        itf = self.policy.interference_from_counters(sample)
        chunk = self.policy.plan_chunk_at(task, [task], itf, now,
                                          self.hw.n_units)
        # the cursor advances by the steps the engine actually EXECUTES
        # (see serve()), not by the planned chunk — a quantum truncated by
        # row budgets or the K-bucket cap must not let block boundaries
        # drift ahead of the work that ran
        self._cursor_n = plan.n_layers
        if chunk is None:
            return 1
        return max(chunk.end_layer - task.next_layer, 1)

    def _active_demands(self, meta: dict, now: float
                        ) -> list[RunningDemand]:
        out = []
        for slot, req in enumerate(self.engine.slot_req):
            if req is None:
                continue
            tenant, _, admit = meta[req.rid]
            bw, cache, ici = self._demand[tenant]
            horizon = admit + self.step_dt * (req.max_new_tokens + 1)
            out.append(RunningDemand(tenant=slot, bw=bw, cache=cache,
                                     ici=ici, start=admit,
                                     finish=max(horizon, now + self.step_dt)))
        return out

    def _admission_pass(self, pending: list, wl: Workload, prompts, lens,
                        meta: dict, rejected: set, deferred_rids: set,
                        shed_rids: set, now: float) -> None:
        """Admit due requests into free slots.  FIFO mode walks the queue
        in arrival order and stops at the first full-engine failure
        (legacy).  SLO mode walks it in earliest-deadline order —
        an urgent late arrival jumps the queue — and consults the
        admission controller, which may shed (drop + count) or defer
        (skip this pass + count) a request before QoS collapses."""
        if self.scheduler == "slo":
            order = sorted(pending,
                           key=lambda p: (self.book.entry(p[2]).deadline,
                                          p[0], p[2]))
        else:
            order = list(pending)
        for t, tenant, rid in order:
            req = Request(rid=rid, prompt=prompts[rid, :lens[rid]],
                          max_new_tokens=wl.max_new_tokens,
                          tier=wl.tier_of(tenant))
            if self.scheduler == "slo" and self.admission is not None:
                entry = self.book.entry(rid)
                pages_needed, pages_free = self.engine.admission_pages(
                    req.prompt, wl.max_new_tokens)
                decision = self.admission.decide(
                    now=now, entry=entry, spec=self.book.spec(entry.tier),
                    step_dt=self.step_dt,
                    own_chunks=len(self.engine._prefill_schedule(lens[rid])),
                    own_decode_steps=wl.max_new_tokens,
                    backlog_chunks=sum(
                        c for _, _, c in self.engine.prefill_queue()),
                    slot_free=self.engine.active_slots < self.engine.slots,
                    pages_needed=pages_needed, pages_free=pages_free)
                if decision == "shed":
                    self.shed += 1
                    shed_rids.add(rid)
                    pending.remove((t, tenant, rid))
                    self.book.drop(rid)
                    continue
                if decision == "defer":
                    if rid not in deferred_rids:
                        deferred_rids.add(rid)
                        self.deferred += 1
                    if self.engine.active_slots >= self.engine.slots:
                        break            # nothing can admit this pass
                    continue
            try:
                admitted = self.engine.admit_request(req)
            except ValueError:
                # inadmissible prompt (len >= max_len would corrupt the
                # cache row): a hard conflict — count once and drop,
                # never retry
                if rid not in rejected:
                    rejected.add(rid)
                    self.conflicts += 1
                pending.remove((t, tenant, rid))
                self.book.drop(rid)
                continue
            if not admitted:
                # engine full: a QoS conflict in the paper's sense,
                # counted once per query at its first failed admission
                if rid not in rejected:
                    rejected.add(rid)
                    self.conflicts += 1
                break
            meta[rid] = (tenant, t, now)
            if req.output:               # monolithic engines prefill
                self._ttft[rid] = now - t   # inside admit_request
            pending.remove((t, tenant, rid))

    def serve(self, wl: Workload) -> ServingMetrics:
        """Replay ``wl`` through the engine; returns ServingMetrics over
        the same records layout the simulator produces."""
        prompts = synth_prompts(wl.n_queries, wl.prompt_len,
                                self.engine.cfg.vocab_size, wl.seed)
        if wl.shared_prefix_len > 0:
            # system-prompt traffic: every query opens with one common
            # token run (deterministic per seed) — on a paged engine the
            # prefix index turns these into refcounted shared pages
            import numpy as np
            spl = min(wl.shared_prefix_len, prompts.shape[1])
            pre = np.random.default_rng(wl.seed + 0x9EF1).integers(
                0, self.engine.cfg.vocab_size, spl)
            prompts[:, :spl] = pre.astype(prompts.dtype)
        lens = wl.prompt_lengths()
        arrivals = collections.deque(
            (t, tenant, rid) for rid, (t, tenant)
            in enumerate(sorted(wl.arrivals)))
        pending: list = []
        meta: dict[int, tuple[str, float, float]] = {}
        rejected: set[int] = set()
        deferred_rids: set[int] = set()
        shed_rids: set[int] = set()
        now = 0.0
        busy = alloc = 0.0

        while arrivals or pending or \
                any(r is not None for r in self.engine.slot_req):
            if self.steps >= self.max_steps:
                break
            while arrivals and arrivals[0][0] <= now:
                t, tenant, rid = arrivals.popleft()
                self.book.register(rid, tenant, wl.tier_of(tenant), t,
                                   self.plans[tenant].qos_s)
                pending.append((t, tenant, rid))
            self._admission_pass(pending, wl, prompts, lens, meta,
                                 rejected, deferred_rids, shed_rids, now)
            n_active = self.engine.active_slots
            if n_active == 0:
                if arrivals:                 # idle: jump to next arrival
                    now = max(now, arrivals[0][0])
                    continue
                break

            # the counter loop: synthesize what the performance counters
            # would read under the live slot occupancy; the policy maps the
            # sample to a level through its calibrated proxy (victim=-1:
            # the engine observes the full co-runner pressure)
            demands = self._active_demands(meta, now)
            sample = read_counters(self.hw, -1, demands, now, self._rng,
                                   source=self.counter_source,
                                   bank=self.engine.counter_bank)
            self.counter_sources[sample.source] += 1
            if self.refit_proxy:
                # realized-pressure label: oracle truth where the sample
                # carries it, else the bank's slowdown-derived estimate
                target = (sample.truth if sample.truth is not None
                          else self.engine.counter_bank.pressure())
                if target is not None:
                    self.policy.observe_counters(sample, target)
            level = self.policy.level_from_counters(sample)
            # the step timer starts BEFORE the version switch: any re-jit /
            # compile the switch triggers is real serving latency (the very
            # overhead adaptive compilation amortizes) and must be charged
            t0 = time.perf_counter()
            self.engine.set_interference_level(level)
            self.compile_time_s += time.perf_counter() - t0
            self.level_trace.append(level)

            # quantum pick.  FIFO mode: prefill chunks and decode quanta
            # strictly alternate while both have work — a long prompt
            # never stalls co-resident decodes for more than one chunk
            # (the granularity claim, applied to the admission path).
            # SLO mode: earliest-deadline order over both queues — a
            # TTFT-urgent prefill chunk preempts decode quanta, batch-
            # tier decodes yield, and a decode quantum's length is
            # capped by the tightest pending TTFT deadline.
            k_cap = self._plan_quantum(meta, sample, now) if self.fused \
                else 1
            pf_slot = None
            if self.scheduler == "slo":
                pick = pick_quantum(self.engine, self.book, now,
                                    self.step_dt, k_cap)
                do_prefill = pick is not None and pick[0] == "prefill"
                if do_prefill:
                    pf_slot = pick[1]
                elif pick is not None:
                    k_cap = pick[1]
            else:
                do_prefill = self.engine.should_prefill(self._prefill_last)
                self._prefill_last = do_prefill
            handle = None
            finished: list = []
            pf = None
            if do_prefill:
                pf = self.engine.prefill_step(pf_slot)
                steps_run = 1
                self.prefill_quanta += 1
                if pf is not None:
                    tier = self.book.get(pf.rid)
                    self.sched_trace.append(
                        ("prefill", pf.rid,
                         tier.tier if tier is not None else None, now))
            elif self.fused:
                handle = self.engine.begin_quantum(k_cap)
                if handle is not None:
                    self.sched_trace.append(("decode", tuple(
                        self.engine.slot_req[i].rid
                        for i in handle.active), now))
                finished = self.engine.finish_quantum(handle)
                steps_run = handle.steps if handle is not None else 1
                if handle is not None:
                    self._cursor = (self._cursor + handle.steps) \
                        % self._cursor_n
                self.quanta += 1
            else:
                handle = self.engine.begin_quantum(1, fused=False)
                if handle is not None:
                    self.sched_trace.append(("decode", tuple(
                        self.engine.slot_req[i].rid
                        for i in handle.active), now))
                finished = self.engine.finish_quantum(handle)
                handle = None           # per-step: legacy time accounting
                steps_run = 1
                self.quanta += 1        # a per-step dispatch is a 1-step
                                        # quantum (comparable records)
            dt = (time.perf_counter() - t0) if self.wall_clock \
                else self.step_dt * steps_run
            self.steps += steps_run
            t_begin = now
            now += dt
            if pf is not None:
                busy += dt                   # the one row being prefilled
                if pf.finished:
                    self._ttft[pf.rid] = now - meta[pf.rid][1]
            elif handle is not None and not self.wall_clock:
                # exact virtual accounting: each row was busy for the
                # steps it actually decoded, not the full quantum
                busy += float(handle.n_left.sum()) * self.step_dt
            else:
                busy += (n_active - self.engine.prefill_pending) * dt
            alloc += self.engine.slots * dt
            for req in finished:
                tenant, arrival, _ = meta[req.rid]
                fin = now
                if handle is not None and not self.wall_clock:
                    # row_steps is in tokens; a speculative quantum emits
                    # up to d+1 of them at its single sync, so the finish
                    # offset is capped at the quantum's clock steps
                    fin = t_begin + min(handle.row_steps[req.rid],
                                        handle.steps) * self.step_dt
                entry = self.book.get(req.rid)
                tiered = wl.tier_of(tenant) is not None
                self.records.append(QueryRecord(
                    tenant=tenant, arrival=arrival, finish=fin,
                    qos_s=self.plans[tenant].qos_s,
                    ttft_s=self._ttft.get(req.rid),
                    tier=(entry.tier if tiered and entry is not None
                          else "standard"),
                    deadline=(entry.deadline if tiered and entry is not None
                              else None)))
                self.outputs[req.rid] = list(req.output)
                self.book.drop(req.rid)

        return summarize(self.records, wl.qps,
                         self.conflicts / max(wl.n_queries, 1), busy, alloc,
                         shed=self.shed, deferred=self.deferred,
                         peak_cache_tokens=self.engine.peak_cache_tokens,
                         cache_utilization=self.engine.cache_utilization,
                         proxy_rms_error=self.policy.proxy_rms_error,
                         refit_count=self.policy.proxy_refits,
                         tokens_accepted=self.engine.tokens_accepted,
                         draft_hit_rate=self.engine.draft_hit_rate,
                         spec_rollbacks=self.engine.spec_rollbacks)
