"""Query/workload generation (MLPerf-Server style).

Arrivals are Poisson with rate lambda = offered QPS (the paper's setup);
mixed workloads draw each query's model with probability inversely
proportional to its QoS target (paper §5.1, following the Google-trace
analysis they cite).  A deterministic uniform generator reproduces the
Fig. 3 experiment (30k identical ResNet-50 queries, uniform arrivals).
"""
from __future__ import annotations

import numpy as np


def poisson_workload(models: list[str], qps: float, n_queries: int,
                     seed: int = 0,
                     weights: list[float] | None = None,
                     ) -> list[tuple[float, str]]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / qps, n_queries)
    times = np.cumsum(gaps)
    if weights is None:
        probs = np.ones(len(models)) / len(models)
    else:
        w = np.asarray(weights, dtype=float)
        probs = w / w.sum()
    names = rng.choice(models, size=n_queries, p=probs)
    return list(zip(times.tolist(), names.tolist()))


def uniform_workload(model: str, qps: float,
                     n_queries: int) -> list[tuple[float, str]]:
    gap = 1.0 / qps
    return [(i * gap, model) for i in range(n_queries)]


def qos_inverse_weights(qos_ms: dict[str, float]) -> list[float]:
    return [1.0 / qos_ms[m] for m in qos_ms]


def synth_prompts(n: int, prompt_len: int, vocab_size: int,
                  seed: int = 0) -> np.ndarray:
    """(n, prompt_len) int32 prompts — deterministic per seed, so a
    Workload replays identically through simulator and engine."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab_size, (n, prompt_len)).astype(np.int32)
