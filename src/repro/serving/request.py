"""Query/workload generation (MLPerf-Server style).

Arrivals are Poisson with rate lambda = offered QPS (the paper's setup);
mixed workloads draw each query's model with probability inversely
proportional to its QoS target (paper §5.1, following the Google-trace
analysis they cite).  A deterministic uniform generator reproduces the
Fig. 3 experiment (30k identical ResNet-50 queries, uniform arrivals).
"""
from __future__ import annotations

import numpy as np


def poisson_workload(models: list[str], qps: float, n_queries: int,
                     seed: int = 0,
                     weights: list[float] | None = None,
                     ) -> list[tuple[float, str]]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / qps, n_queries)
    times = np.cumsum(gaps)
    if weights is None:
        probs = np.ones(len(models)) / len(models)
    else:
        w = np.asarray(weights, dtype=float)
        probs = w / w.sum()
    names = rng.choice(models, size=n_queries, p=probs)
    return list(zip(times.tolist(), names.tolist()))


def uniform_workload(model: str, qps: float,
                     n_queries: int) -> list[tuple[float, str]]:
    gap = 1.0 / qps
    return [(i * gap, model) for i in range(n_queries)]


def _pick_models(rng: np.random.Generator, models: list[str], n: int,
                 weights: list[float] | None) -> np.ndarray:
    if weights is None:
        probs = np.ones(len(models)) / len(models)
    else:
        w = np.asarray(weights, dtype=float)
        probs = w / w.sum()
    return rng.choice(models, size=n, p=probs)


def gamma_poisson_workload(models: list[str], qps: float, n_queries: int,
                           *, burstiness: float = 1.0,
                           interval_s: float = 0.05, seed: int = 0,
                           weights: list[float] | None = None,
                           ) -> list[tuple[float, str]]:
    """Doubly-stochastic (Gamma-modulated) Poisson arrivals — the bursty
    heavy-traffic regime the paper targets.

    The instantaneous rate is ``qps * m_i`` where the per-interval
    multiplier ``m_i ~ Gamma(shape=1/burstiness, scale=burstiness)``
    (mean 1, variance = burstiness), redrawn every ``interval_s``
    seconds: ``burstiness -> 0`` recovers plain Poisson at rate ``qps``;
    large values pile arrivals into flash crowds separated by lulls.
    Mean offered load stays ``qps`` so bursty and smooth workloads are
    comparable at equal offered load."""
    if burstiness < 0:
        raise ValueError("burstiness must be >= 0")
    rng = np.random.default_rng(seed)
    times = []
    t = 0.0
    while len(times) < n_queries:
        if burstiness < 1e-9:
            mult = 1.0
        else:
            mult = float(rng.gamma(1.0 / burstiness, burstiness))
        rate = qps * mult
        end = t + interval_s
        if rate > 1e-12:
            while True:
                t += float(rng.exponential(1.0 / rate))
                if t >= end or len(times) >= n_queries:
                    break
                times.append(t)
        t = end
    names = _pick_models(rng, models, n_queries, weights)
    return list(zip(times[:n_queries], names.tolist()))


def diurnal_workload(models: list[str], qps_peak: float, n_queries: int,
                     *, period_s: float = 1.0, floor: float = 0.2,
                     seed: int = 0, weights: list[float] | None = None,
                     ) -> list[tuple[float, str]]:
    """Sinusoidally-modulated Poisson arrivals (a compressed diurnal
    cycle) via Lewis thinning: rate(t) = qps_peak * (floor + (1-floor)
    * (1 + sin(2*pi*t/period_s)) / 2), so load swings between
    ``floor*qps_peak`` and ``qps_peak`` every ``period_s`` seconds."""
    if not 0.0 <= floor <= 1.0:
        raise ValueError("floor must be in [0, 1]")
    rng = np.random.default_rng(seed)
    times = []
    t = 0.0
    while len(times) < n_queries:
        t += float(rng.exponential(1.0 / qps_peak))
        rate_frac = floor + (1.0 - floor) \
            * (1.0 + np.sin(2.0 * np.pi * t / period_s)) / 2.0
        if rng.random() < rate_frac:        # Lewis-Shedler thinning
            times.append(t)
    names = _pick_models(rng, models, n_queries, weights)
    return list(zip(times, names.tolist()))


def qos_inverse_weights(qos_ms: dict[str, float]) -> list[float]:
    return [1.0 / qos_ms[m] for m in qos_ms]


def synth_prompts(n: int, prompt_len: int, vocab_size: int,
                  seed: int = 0) -> np.ndarray:
    """(n, prompt_len) int32 prompts — deterministic per seed, so a
    Workload replays identically through simulator and engine."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab_size, (n, prompt_len)).astype(np.int32)
