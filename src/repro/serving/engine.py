"""Batched serving engine (real JAX execution path).

Wraps a model's prefill/decode with continuous batching over request
slots: requests join free slots, prefill fills their cache rows, decode
steps run the whole batch, finished rows free their slots.  Decode is
*per-slot*: every slot advances at its own absolute position with its own
kv-valid horizon, so staggered admissions and mixed-length prompts are
exact — each slot's tokens match a sequential one-request-at-a-time
reference.  This is the engine the examples drive on CPU with reduced
models; at pod scale the same functions are jitted with the serve-mode
shardings (launch/serve.py).

Admission is chunked and length-bucketed (``chunked_prefill=True``):
``admit_request`` validates the prompt and queues power-of-two-bucketed
prefill chunks; ``prefill_step`` runs one chunk — the prefill-side
dispatch quantum — into the slot's accumulating row cache.  Compiled
prefill shapes are the bucket table, never the prompt-length
distribution, so mixed-length traffic performs zero post-warmup
retraces, and the runtimes interleave chunks with decode quanta so a
long prompt cannot stall co-resident decodes (docs/ARCHITECTURE.md §5).

The VELTAIR integration point: ``set_interference_level`` selects the
code version the adaptive compiler produced for that pressure — either
from a compiled ``VersionSet`` (the multi-version tables of an analytical
ModelPlan) or from the built-in level table, which shrinks tiles as
pressure rises (locality -> parallelism, paper Fig. 6/9).  Executables
come from a per-engine :class:`~repro.serving.version_cache.VersionCache`
keyed by the tile configuration: every version is traced once (its tiles
baked in through a ``kernels.dispatch.tile_context``), after which a
level switch is a dictionary swap of already-compiled callables — no
retrace, and no interference between engines sharing the process.
``warmup()`` pre-builds the whole table ahead of time.  The engine is
oblivious to how the level was derived; repro.serving.runtime queries the
scheduling policy for it every step.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cost_model as cm
from repro.core.counters import CounterBank
from repro.kernels import dispatch
from repro.models.model import Model, build_model, cache_batch_axis, path_keys
from repro.serving.paging import TRASH_PAGE, PagePool
from repro.serving.speculative import NgramDrafter
from repro.serving.version_cache import VersionCache

# Fused-quantum executable sizes: a quantum of k decode steps runs as the
# smallest warmed bucket >= k (rows past their budget freeze on device, so
# an oversized bucket stays token-exact and only wastes the frozen tail).
# Quanta larger than the top bucket split into multiple fused calls.
QUANTUM_BUCKETS = (1, 2, 4, 8, 16)

# Default prefill chunk: prompts are split into chunks of this many
# tokens, each a schedulable quantum; the tail is padded UP to a
# power-of-two bucket, so the compiled prefill shapes are the bucket
# table {1, 2, ..., PREFILL_CHUNK_LEN}, not the prompt-length
# distribution — mixed-length traffic performs zero post-warmup retraces.
PREFILL_CHUNK_LEN = 16


def _next_pow2(n: int) -> int:
    return 1 << (max(int(n), 1) - 1).bit_length()

# Built-in interference-level -> tile table (one entry per grid level).
# Low pressure: big tiles, maximal reuse of the shared cache; high
# pressure: small private-cache-resident tiles that cede the LLC.
_LEVEL_TILE_SIZES = (256, 224, 192, 160, 128, 112, 96, 80, 64, 48)
DEFAULT_LEVEL_TILES = tuple(
    {"matmul": {"bm": s, "bk": 2 * s, "bn": s},
     "attention": {"bq": max(s, 64), "bkv": max(2 * s, 128)}}
    for s in _LEVEL_TILE_SIZES)
assert len(DEFAULT_LEVEL_TILES) == cm.NUM_LEVELS


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 16
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    tier: str | None = None       # SLO tier (core.qos.TIER_ORDER); None =
                                  # untiered legacy request (standard urgency,
                                  # legacy qos_s-relative satisfaction)


@dataclasses.dataclass
class _PrefillState:
    """An in-flight chunked prefill occupying a slot (not yet decodable)."""
    req: Request
    row_cache: object              # accumulating batch-1 row cache
    schedule: collections.deque    # remaining chunk sizes (bucket table)
    done: int = 0                  # real prompt tokens prefilled so far


@dataclasses.dataclass
class PrefillQuantum:
    """Result of one executed prefill chunk (``prefill_step``)."""
    slot: int
    rid: int
    chunk: int                     # padded chunk size dispatched
    tokens: int                    # real prompt tokens consumed
    finished: bool                 # prompt fully prefilled, first token out


@dataclasses.dataclass
class QuantumHandle:
    """An in-flight fused dispatch quantum.

    ``begin_quantum`` returns one of these *without* syncing: ``block``
    is still an on-device (possibly not-yet-computed) array, so a caller
    co-locating several engines can issue every engine's quantum before
    blocking on any of them — the device work overlaps instead of
    serializing through Python.  ``finish_quantum`` performs the single
    device->host sync and the request bookkeeping."""
    block: jax.Array               # (K, B) int32 on-device token block
    n_left: np.ndarray             # (B,) per-row steps actually budgeted
    steps: int                     # quantum length (max over rows)
    active: list[int]              # slots live at dispatch time
    row_steps: dict = dataclasses.field(default_factory=dict)  # rid -> steps
    # measured-counter bookkeeping: t0 is stamped AFTER the version-cache
    # lookup (and any AOT compile it performed), so the wall time closed
    # out by finish_quantum covers device work only — host-side scheduling
    # and compile time are charged by the runtimes, never double-counted
    # here.  traces0 snapshots the version-cache trace counter; a quantum
    # that traced inside its timed span is not observed at all.
    t0: float = 0.0                # perf_counter at dispatch (0 = untimed)
    traces0: int = -1              # version-cache traces at dispatch
    bucket: int = 0                # K-bucket the executable ran
    tiles: tuple = ()              # tiles key of the dispatched version
    # speculative quanta: kind == "spec" carries the on-device per-row
    # emission counts / pure acceptance counts; finish_quantum folds the
    # synced emission back into n_left so downstream accounting is shared
    kind: str = "decode"           # "decode" | "spec"
    emitted: jax.Array | None = None    # (B,) device n_emit (spec only)
    accepted: jax.Array | None = None   # (B,) device acceptance (spec only)
    drafted: int = 0               # draft depth dispatched (spec only)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 version_sets: list | None = None,
                 quantum_buckets: tuple[int, ...] = QUANTUM_BUCKETS,
                 chunked_prefill: bool = True,
                 prefill_chunk_len: int = PREFILL_CHUNK_LEN,
                 page_size: int | None = None, n_pages: int | None = None,
                 page_reserve: str = "worst", prefix_sharing: bool = True,
                 ladder=None, speculative: bool = False,
                 spec_depth: int = 4, spec_ngram: int = 3,
                 spec_recurrent: bool = True):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        # paged KV cache: linear-attention cache leaves live in a global
        # page pool indexed through a per-slot page table; memory becomes
        # a scheduler-visible dimension (PagePool commitments gate
        # admission, free-page headroom clamps decode quanta) and common
        # prompt prefixes are deduplicated across requests (refcounted
        # shared pages + copy-on-write).  page_size=None keeps the dense
        # per-slot row layout.
        self.paged = page_size is not None
        self.page_size = int(page_size) if self.paged else 0
        self.page_reserve = page_reserve
        if self.paged:
            if self.page_size < 1 or max_len % self.page_size:
                raise ValueError(
                    f"page_size={page_size} must be >= 1 and divide "
                    f"max_len={max_len}")
            if page_reserve not in ("worst", "prompt"):
                raise ValueError(
                    f"page_reserve={page_reserve!r} not in ('worst', "
                    "'prompt')")
            self._paged_paths = self.model.paged_leaf_paths()
            if not self._paged_paths:
                raise ValueError(
                    f"{cfg.name}: no pageable (linear-KV) cache leaves — "
                    "recurrent-state models keep the dense layout")
            self.pages_per_slot = max_len // self.page_size
            if n_pages is None:
                n_pages = batch_slots * self.pages_per_slot
            self.pool: PagePool | None = PagePool(int(n_pages),
                                                 self.page_size)
            # prefix sharing splices pool pages under a partially-dense
            # row, so it needs every seq-axis leaf paged (pure-attention
            # families; hybrids would leak recurrent state)
            self.prefix_sharing = bool(prefix_sharing) \
                and self.model.all_cache_leaves_paged()
            self.cache = self.model.init_paged_cache(
                batch_slots, max_len, int(n_pages), self.page_size)
            # host mirror of the device page table + per-slot page maps
            self._page_table = np.zeros((batch_slots, self.pages_per_slot),
                                        np.int32)
            self._table_dirty = False
            self._slot_pages: list[dict[int, int]] = [
                {} for _ in range(batch_slots)]     # logical -> physical
            self._slot_shared: list[set[int]] = [
                set() for _ in range(batch_slots)]  # borrowed (COW-guarded)
            self._slot_commit = [0] * batch_slots   # reserved, unallocated
        else:
            self._paged_paths = frozenset()
            self.pages_per_slot = 0
            self.pool = None
            self.prefix_sharing = False
            self.cache = self.model.init_cache(batch_slots, max_len)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        # chunked, length-bucketed admission (the scheduled-prefill path):
        # chunk sizes are powers of two <= prefill_chunk_len, clamped so a
        # padded tail can never write past the cache's max_len rows
        self.chunked_prefill = chunked_prefill
        self.prefill_chunk_len = min(_next_pow2(prefill_chunk_len),
                                     _next_pow2(max_len + 1) // 2 or 1)
        self.prefill_buckets = tuple(
            1 << i for i in range(self.prefill_chunk_len.bit_length()))
        self._prefill: dict[int, _PrefillState] = {}   # slot -> state (FIFO)
        self.prefill_chunks = 0        # chunk quanta executed
        self.prefill_tokens = 0        # real prompt tokens prefilled
        self.prefill_pad_tokens = 0    # bucket-padding tokens (waste)
        self.rejected_invalid = 0      # admissions refused for length
        # pristine single-slot cache row: admissions prefill from this so a
        # reused slot can never leak the previous tenant's KV / SSM state.
        # Paged engines prefill into a DENSE batch-1 row (chunk kernels are
        # layout-oblivious) and scatter it into the pools page-by-page at
        # finish, so the empty row is a dense row either way.
        self._empty_row = (self.model.init_cache(1, max_len) if self.paged
                           else self._slice_row(0))
        # adaptive-compilation state: tiles come from the dominant layer's
        # multi-version table when one is supplied; else from an autotuned
        # level ladder (the ``ladder`` argument — a LadderSpec or its raw
        # levels list — or, when neither is given, the process-global
        # ladder dispatch.load_ladder() installed); else the built-in
        # DEFAULT_LEVEL_TILES.  The ladder is snapshotted at build time so
        # later global installs never change a live engine's versions.
        self.version_sets = version_sets
        self._tile_source = (max(version_sets,
                                 key=lambda vs: vs.solo_version().flops)
                             if version_sets else None)
        lad = ladder if ladder is not None else dispatch.active_ladder()
        if lad is not None and hasattr(lad, "levels"):
            lad = lad.levels
        if lad is not None:
            if len(lad) != cm.NUM_LEVELS:
                raise ValueError(f"ladder has {len(lad)} levels, expected "
                                 f"{cm.NUM_LEVELS}")
            self._ladder = [{op: dict(kw) for op, kw in lvl.items()}
                            for lvl in lad]
        else:
            self._ladder = None
        # measured-counter loop: per-quantum wall times feed this bank;
        # the runtimes poll it through read_counters(source="measured").
        # co_runner_load is stamped by the cluster runtime before each
        # dispatch (observability on the recorded observations).
        self.counter_bank = CounterBank()
        self.co_runner_load = 0
        self.interference_level = 0.0
        self._active_tiles: dict | None = None
        self.level_switches = 0           # distinct-version switch count
        self.quantum_buckets = tuple(sorted(set(
            int(b) for b in quantum_buckets)))
        if not self.quantum_buckets or self.quantum_buckets[0] < 1:
            raise ValueError("quantum_buckets must be positive ints")
        # dispatch-granularity counters: the fused-quantum win is measured,
        # not asserted — tokens_per_sync is the tokens decoded per
        # device->host sync (1.0 on the per-step path, up to K fused)
        self.host_syncs = 0
        self.tokens_decoded = 0
        self.quantum_calls = 0
        # speculative decode quanta: a prompt-lookup drafter proposes up
        # to spec_depth tokens per row; one batched verify forward scores
        # them all (Model.verify_quantum) and the longest matching prefix
        # plus a corrected token is emitted.  Recurrent-state families
        # need the verify's restore pass; spec_recurrent=False turns
        # speculation off for them (plain-quantum fallback) instead.
        self.speculative = bool(speculative)
        self.spec_depth = int(spec_depth)
        if self.speculative and self.spec_depth < 1:
            raise ValueError("spec_depth must be >= 1")
        self._spec_enabled = self.speculative and (
            bool(spec_recurrent)
            or not self.model._has_nonseq_cache_leaves())
        self.drafter = (NgramDrafter(depth=self.spec_depth,
                                     max_ngram=int(spec_ngram))
                        if self.speculative else None)
        self.spec_quanta = 0       # speculative quanta dispatched
        self.spec_fallbacks = 0    # spec-eligible dispatches that fell back
        self.tokens_drafted = 0    # draft tokens submitted to verify
        self.tokens_accepted = 0   # draft tokens accepted (emitted past the
                                   # guaranteed corrected token)
        self.spec_rollbacks = 0    # row-quanta where a draft was rejected
        self._spec_accept_ewma = 1.0   # emitted tokens per spec dispatch
        self.version_cache = VersionCache(self.model)
        # per-engine row writer: O(row) in-place admission (donated cache +
        # dynamic_update_slice along the batch axis; slot is a traced
        # scalar, so one executable serves every slot)
        self._row_writer = self._make_row_writer()
        if self.paged:
            self._paged_row_writer = self._make_paged_row_writer()
            self._row_gather = self._make_row_gather()
            self._page_copier = self._make_page_copier()
        # occupancy telemetry (ServingMetrics.peak_cache_tokens /
        # cache_utilization sample these)
        self.peak_cache_tokens = 0
        self.peak_active_slots = 0
        self._use_version({})             # baseline: no overrides installed

    # ------------------------------------------------------------------
    def _use_version(self, tiles: dict) -> None:
        entry = self.version_cache.get(tiles)
        self._entry = entry
        self._prefill_one = entry.prefill
        self._prefill_chunk = entry.prefill_chunk
        self._decode = entry.decode

    @property
    def tokens_per_sync(self) -> float:
        return self.tokens_decoded / max(self.host_syncs, 1)

    @property
    def draft_hit_rate(self) -> float:
        """Accepted draft tokens / drafted tokens (0.0 before any spec
        quantum ran)."""
        return self.tokens_accepted / max(self.tokens_drafted, 1)

    @property
    def spec_stats(self) -> dict:
        """Speculative-decode counters for metrics / bench reports."""
        return {"spec_quanta": self.spec_quanta,
                "spec_fallbacks": self.spec_fallbacks,
                "tokens_drafted": self.tokens_drafted,
                "tokens_accepted": self.tokens_accepted,
                "draft_hit_rate": self.draft_hit_rate,
                "spec_rollbacks": self.spec_rollbacks}

    def expected_accept_per_step(self) -> float:
        """Expected tokens emitted per dispatched decode step (>= 1.0;
        1.0 exactly for non-speculative engines).  The SLO scheduler's
        EDF slack math multiplies its step budget by this, so a request
        whose remaining tokens would not fit the deadline at one
        token/step stays schedulable when speculation is landing
        multi-token quanta (an EWMA of recent acceptance)."""
        if not self._spec_enabled:
            return 1.0
        return max(1.0, float(self._spec_accept_ewma))

    def tiles_for_level(self, level: float) -> dict:
        """The tile table the compiled source selects at ``level``."""
        return self._tiles_for(cm.Interference.from_level(level))

    def _tiles_for(self, itf: cm.Interference) -> dict:
        if self._tile_source is not None:
            v = self._tile_source.select(itf)
            return {"matmul": {"bm": int(v.bm), "bk": int(v.bk),
                               "bn": int(v.bn)}}
        if self._ladder is not None:
            lvl = self._ladder[cm.level_to_idx(itf.level)]
            return {op: dict(kw) for op, kw in lvl.items()}
        return DEFAULT_LEVEL_TILES[cm.level_to_idx(itf.level)]

    def set_interference_level(self, level: float) -> dict:
        """Switch the active code version to the one compiled for
        ``level`` (0.0 = solo .. 1.0 = heavy co-location).

        Swaps in the version-cache entry for the matching tile
        configuration (already-compiled executables after ``warmup()`` or
        a prior visit — never a retrace) and atomically installs the same
        tiles in the process-global dispatch table for observability /
        out-of-engine callers: ops the new source does not override are
        cleared, so no stale per-op entry survives a source switch.
        Returns the installed override dict (observability / tests)."""
        itf = cm.Interference.from_level(level)
        tiles = self._tiles_for(itf)
        if tiles != self._active_tiles:
            dispatch.install_tile_overrides(tiles)
            self._use_version(tiles)
            self._active_tiles = tiles
            self.level_switches += 1
        self.interference_level = itf.level
        return {op: dict(kw) for op, kw in tiles.items()}

    def warmup(self, prompt_lens: tuple[int, ...] = (),
               levels: list[float] | None = None,
               quantum_buckets: tuple[int, ...] | None = None) -> dict:
        """Ahead-of-time build AND execute the executables of every
        interference level (default: the full NUM_LEVELS grid), so later
        ``set_interference_level`` calls are dictionary swaps and the step
        that follows them never traces or compiles.

        Decode is shape-stable and always warmed.  On the chunked
        admission path every prefill-chunk bucket is warmed too, so
        mixed-length traffic never retraces — ``prompt_lens`` is only
        needed for the monolithic (``chunked_prefill=False``) path, whose
        prefill specializes per exact length.  Every fused K-bucket
        executable is AOT-compiled alongside (against abstract cache
        shapes — no decode steps run for them), so the first
        ``step_quantum`` after warmup never traces either; pass
        ``quantum_buckets`` to warm a subset.  Memory: one compiled
        decode + one fused executable per (distinct tile configuration,
        K-bucket), one chunked prefill per (configuration, chunk bucket),
        plus one compiled prefill per (configuration, length in
        ``prompt_lens``).  Returns the version-cache stats snapshot."""
        if levels is None:
            levels = [cm.grid_point(i) for i in range(cm.NUM_LEVELS)]
        buckets = (self.quantum_buckets if quantum_buckets is None
                   else tuple(quantum_buckets))
        # the warm decode calls below donate self.cache and run at pos=0,
        # so snapshot any resident request rows and restore them after —
        # warming up mid-serving must not corrupt in-flight KV/SSM state
        live_rows = [(i, self._slice_row(i))
                     for i, r in enumerate(self.slot_req) if r is not None]
        if self.paged:
            # aim every slot at the trash page while warm decodes run:
            # their garbage writes land there, never in live pool pages
            self.cache["page_table"] = jnp.zeros_like(
                self.cache["page_table"])
            self._table_dirty = True
        toks = jnp.zeros((self.slots,), jnp.int32)
        pos = jnp.zeros((self.slots,), jnp.int32)
        # the currently-active version first (the no-override baseline an
        # engine serves with before its first level is set), then the table
        tile_tables = [self._active_tiles if self._active_tiles is not None
                       else {}]
        tile_tables += [self.tiles_for_level(lv) for lv in levels]
        for entry in self.version_cache.warmup(tile_tables):
            # decode donates its cache: adopt the returned one (numerics
            # are irrelevant here — live rows are always re-prefilled from
            # the pristine row at admission)
            logits, self.cache = entry.decode(self.params, {"tokens": toks},
                                              self.cache, pos)
            logits.block_until_ready()
            for k in buckets:
                self.version_cache.quantum(entry, k, self.params,
                                           self.cache, self.slots)
            if self._spec_enabled:
                # every reachable (bucket, depth) pair: the dispatch
                # bucket is the smallest one covering min(k, d+1), so
                # buckets above that are never requested
                cap = min(self.spec_depth + 1, self.quantum_buckets[-1])
                top = next(b for b in self.quantum_buckets if b >= cap)
                for k in buckets:
                    if k <= top:
                        self.version_cache.spec_quantum(
                            entry, k, self.spec_depth, self.params,
                            self.cache, self.slots)
            if self.chunked_prefill:
                for cb in self.prefill_buckets:
                    lg, _ = entry.prefill_chunk(
                        self.params, jnp.zeros((1, cb), jnp.int32),
                        self._empty_row, jnp.int32(0), jnp.int32(cb))
                    lg.block_until_ready()
            for plen in prompt_lens:
                lg, _ = entry.prefill(
                    self.params, jnp.zeros((1, int(plen)), jnp.int32),
                    self._empty_row)
                lg.block_until_ready()
        if self.paged:
            # warm the engine-level paged helpers too (first admission /
            # COW must not compile mid-serving); all writes hit trash
            trash = jnp.zeros(self.pages_per_slot, jnp.int32)
            self._row_gather(self.cache, self._empty_row, trash)
            self.cache = self._page_copier(self.cache, jnp.int32(0),
                                           jnp.int32(0))
            for i, row in live_rows:
                self.cache = self._paged_row_writer(self.cache, row,
                                                    jnp.int32(i), trash)
            if not live_rows:
                self.cache = self._paged_row_writer(
                    self.cache, self._empty_row, jnp.int32(0), trash)
            self._sync_table()       # restore the real table from the mirror
        else:
            for i, row in live_rows:
                self.cache = self._row_writer(self.cache, row, jnp.int32(i))
        return dict(self.version_cache.stats)

    @property
    def active_slots(self) -> int:
        """Occupied request slots right now (the cluster runtime's live
        occupancy signal: co-runner demand is synthesized per occupied
        slot, so this is what the interference counters 'see')."""
        return sum(r is not None for r in self.slot_req)

    # ------------------------------------------------------------------
    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def _slice_row(self, slot: int):
        """Snapshot a slot as a dense batch-1 row cache.  On the paged
        engine only the dense (recurrent-state) leaves carry per-slot
        data worth saving — pool leaves are shared across slots and
        survive in place — so paged leaves come back as zero rows and the
        restoring write scatters them to the trash page."""
        if not self.paged:
            return jax.tree_util.tree_map_with_path(
                lambda p, c: jax.lax.slice_in_dim(c, slot, slot + 1,
                                                  axis=cache_batch_axis(p)),
                self.cache)
        paths = self._paged_paths
        max_len = self.max_len

        def f(p, c):
            keys = path_keys(p)
            if keys in paths:
                if keys[0] == "blocks":
                    shape = (c.shape[0], 1, max_len, *c.shape[3:])
                else:
                    shape = (1, max_len, *c.shape[2:])
                return jnp.zeros(shape, c.dtype)
            return jax.lax.slice_in_dim(c, slot, slot + 1,
                                        axis=cache_batch_axis(p))
        body = {k: v for k, v in self.cache.items() if k != "page_table"}
        return jax.tree_util.tree_map_with_path(f, body)

    @staticmethod
    def _make_row_writer():
        """Jitted O(row) slot write: the batched cache is donated (updated
        in place) and the row lands via ``dynamic_update_slice_in_dim`` on
        its batch axis — admission cost scales with one row, not with the
        whole (slots, max_len) cache.  ``slot`` is a traced scalar, so a
        single executable serves every slot."""
        def write(cache, row_cache, slot):
            def put(p, c, r):
                return jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=cache_batch_axis(p))
            return jax.tree_util.tree_map_with_path(put, cache, row_cache)
        return jax.jit(write, donate_argnums=(0,))

    def _make_paged_row_writer(self):
        """Paged counterpart of the row writer: the dense batch-1 row is
        reshaped into pages and scattered to the physical destinations in
        ``wtab`` (pages_per_slot,) int32.  Entries mapped to the trash
        page absorb the content of shared / unallocated logical pages
        (borrowed prefixes must not be overwritten); dense leaves — the
        recurrent state of hybrid models — land on their batch axis as in
        the dense writer.  The device page table passes through
        untouched (it is host-owned, refreshed by ``_sync_table``)."""
        paths = self._paged_paths
        n_slot, ps = self.pages_per_slot, self.page_size

        def write(cache, row_cache, slot, wtab):
            body = {k: v for k, v in cache.items() if k != "page_table"}

            def put(p, c, r):
                keys = path_keys(p)
                if keys in paths:
                    if keys[0] == "blocks":
                        rp = r.reshape(r.shape[0], n_slot, ps, *r.shape[3:])
                        return c.at[:, wtab].set(rp.astype(c.dtype))
                    rp = r.reshape(n_slot, ps, *r.shape[2:])
                    return c.at[wtab].set(rp.astype(c.dtype))
                return jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=cache_batch_axis(p))
            out = jax.tree_util.tree_map_with_path(put, body, row_cache)
            out["page_table"] = cache["page_table"]
            return out
        return jax.jit(write, donate_argnums=(0,))

    def _make_row_gather(self):
        """Materialize a slot's mapped pages into a dense batch-1 row —
        the shared-prefix admission path: borrowed pages land at their
        logical offsets so the unshared tail can prefill on top of them.
        ``trow`` entries still unmapped read the trash page; that garbage
        sits at positions the remaining chunks overwrite before any query
        attends to it.  Dense leaves keep the pristine empty row's
        state."""
        paths = self._paged_paths
        n_slot, ps = self.pages_per_slot, self.page_size

        def gather(cache, row_cache, trow):
            body = {k: v for k, v in cache.items() if k != "page_table"}

            def g(p, c, r):
                keys = path_keys(p)
                if keys not in paths:
                    return r
                if keys[0] == "blocks":
                    return c[:, trow].reshape(
                        c.shape[0], 1, n_slot * ps,
                        *c.shape[3:]).astype(r.dtype)
                return c[trow].reshape(1, n_slot * ps,
                                       *c.shape[2:]).astype(r.dtype)
            return jax.tree_util.tree_map_with_path(g, body, row_cache)
        return jax.jit(gather)

    def _make_page_copier(self):
        """Copy-on-write kernel: duplicate physical page ``src`` into
        ``dst`` across every pool leaf (one logical page occupies the
        same physical index in every layer's pool).  Traced scalars, so
        one executable serves every (src, dst) pair; the cache is donated
        (in-place update)."""
        paths = self._paged_paths

        def copy(cache, src, dst):
            def cp(p, c):
                keys = path_keys(p)
                if keys not in paths:
                    return c
                ax = 1 if keys[0] == "blocks" else 0
                page = jax.lax.dynamic_slice_in_dim(c, src, 1, axis=ax)
                return jax.lax.dynamic_update_slice_in_dim(c, page, dst,
                                                           axis=ax)
            return jax.tree_util.tree_map_with_path(cp, cache)
        return jax.jit(copy, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # Page accounting (paged engines only)
    # ------------------------------------------------------------------
    def _sync_table(self) -> None:
        """Push the host page-table mirror to the device when stale.  The
        table rides inside the cache pytree, so every compiled executable
        already takes it — no signature change, no retrace."""
        if self.paged and self._table_dirty:
            self.cache["page_table"] = jnp.asarray(self._page_table)
            self._table_dirty = False

    def _alloc_page(self, slot: int) -> int | None:
        """One physical page for ``slot``, drawing down its admission
        commitment first (those draws cannot fail by construction);
        uncommitted draws may return None when the pool's free surplus is
        exhausted (counted as a stall by the pool)."""
        assert self.pool is not None
        if self._slot_commit[slot] > 0:
            self._slot_commit[slot] -= 1
            return self.pool.alloc(reserved=True)
        return self.pool.alloc(reserved=False)

    def _probe_prefix(self, prompt) -> tuple[list, tuple | None]:
        """Published pages covering a prefix of ``prompt``: the list of
        full-page hits [(logical, physical), ...] plus an optional
        partial-tail hit — a published page whose token span *covers* the
        entire remaining prompt (the borrower attends only to its own
        prefix of the page; positions beyond are causally masked until
        copy-on-write privatizes them)."""
        assert self.pool is not None
        ps = self.page_size
        toks = tuple(int(t) for t in prompt)
        n = len(toks)
        shared: list[tuple[int, int]] = []
        j = 0
        while (j + 1) * ps <= n:
            phys = self.pool.lookup(toks[:j * ps], toks[j * ps:(j + 1) * ps])
            if phys is None:
                break
            shared.append((j, phys))
            j += 1
        partial = None
        rem = toks[j * ps:]
        if rem and len(rem) < ps:
            phys = self.pool.lookup_covering(toks[:j * ps], rem)
            if phys is not None:
                partial = (j, phys)
        return shared, partial

    def admission_pages(self, prompt,
                        max_new_tokens: int) -> tuple[int, int | None]:
        """(pages_needed, pages_free) for the admission controller: the
        worst-case pages this request would commit (net of shareable
        prefix pages) and the pool's uncommitted free surplus.  Dense
        engines report (0, None) — memory is not a conflict dimension
        there."""
        if not self.paged:
            return 0, None
        assert self.pool is not None
        n = len(prompt)
        shared: list = []
        if self.prefix_sharing and self.chunked_prefill:
            shared, _ = self._probe_prefix(prompt)
        horizon = (n + max(int(max_new_tokens), 1)
                   if self.page_reserve == "worst" else n + 1)
        need = self.pool.pages_for(min(horizon, self.max_len)) - len(shared)
        return max(need, 0), self.pool.uncommitted_free

    def _paged_admit(self, req: Request, slot: int,
                     n: int) -> tuple[int, object] | None:
        """Page-pool side of admission: probe the prefix index, commit
        the worst-case page budget, map shared pages (refcounted) and
        allocate owned pages covering the unshared prompt region.
        Returns (start, row_cache) — the prefill start offset (shared
        tokens skip prefill; the final prompt token always prefills so
        the first-token logits exist) and the row to prefill into — or
        None when the pool cannot commit (counted as a page conflict)."""
        assert self.pool is not None
        pool, ps = self.pool, self.page_size
        shared: list[tuple[int, int]] = []
        partial: tuple | None = None
        if self.prefix_sharing and self.chunked_prefill:
            shared, partial = self._probe_prefix(req.prompt)
        horizon = (n + max(req.max_new_tokens, 1)
                   if self.page_reserve == "worst" else n + 1)
        commit = max(
            pool.pages_for(min(horizon, self.max_len)) - len(shared), 0)
        if not pool.commit(commit):
            return None
        self._slot_commit[slot] = commit
        pages = self._slot_pages[slot]
        borrowed = self._slot_shared[slot]
        pages.clear()
        borrowed.clear()
        trow = self._page_table[slot]
        trow[:] = TRASH_PAGE
        shared_len = len(shared) * ps
        if partial is not None:
            shared = shared + [partial]
            shared_len = n
        for j, phys in shared:
            pool.retain(phys)
            pool.shared_hits += 1
            pages[j] = phys
            borrowed.add(j)
            trow[j] = phys
        # owned pages covering the rest of the prompt (commitment covers
        # every one of them, so these allocations cannot fail)
        for j in range(len(shared), pool.pages_for(n)):
            phys = self._alloc_page(slot)
            assert phys is not None
            pages[j] = phys
            trow[j] = phys
        self._table_dirty = True
        # the final prompt token must prefill even when fully shared:
        # its forward pass produces the first-token logits
        start = min(shared_len, n - 1)
        if start > 0:
            row = self._row_gather(self.cache, self._empty_row,
                                   jnp.asarray(trow))
        else:
            row = self._empty_row
        return start, row

    def _write_table(self, slot: int) -> np.ndarray:
        """Scatter destinations for a finished prefill row: owned pages
        keep their physical index, borrowed and unmapped pages divert to
        the trash page (their content either already lives in the pool or
        was never real)."""
        wtab = np.full(self.pages_per_slot, TRASH_PAGE, np.int32)
        borrowed = self._slot_shared[slot]
        for j, phys in self._slot_pages[slot].items():
            if j not in borrowed:
                wtab[j] = phys
        return wtab

    def _publish_slot_pages(self, slot: int, req: Request) -> None:
        """Advertise the slot's owned FULL prompt pages in the pool's
        prefix index.  Partial tail pages are never published — decode
        writes into them, and unpublished pages need no COW for their
        owner (published spans end at or before the prompt, decode writes
        strictly after, so an owner never writes its own published
        page)."""
        if not (self.paged and self.prefix_sharing):
            return
        assert self.pool is not None
        ps = self.page_size
        toks = tuple(int(t) for t in req.prompt)
        n = len(toks)
        borrowed = self._slot_shared[slot]
        for j, phys in self._slot_pages[slot].items():
            if j not in borrowed and (j + 1) * ps <= n:
                self.pool.publish(toks[:j * ps], toks[j * ps:(j + 1) * ps],
                                  phys)

    def release_slot(self, slot: int) -> None:
        """Invalidate a freed slot's cache state before reuse — the
        completion-side half of the pristine-row guarantee (admission
        writes a pristine row; release must not leave the previous
        tenant's state reachable).  Dense: scatter the empty row over the
        slot.  Paged: drop the slot's page references (a page frees when
        its last holder leaves; published pages another request still
        shares survive), return unused commitment, and park the table row
        on the trash page."""
        self.slot_req[slot] = None
        self.slot_pos[slot] = 0
        if not self.paged:
            self.cache = self._row_writer(self.cache, self._empty_row,
                                          jnp.int32(slot))
            return
        assert self.pool is not None
        for phys in self._slot_pages[slot].values():
            self.pool.release(phys)
        self._slot_pages[slot].clear()
        self._slot_shared[slot].clear()
        self.pool.uncommit(self._slot_commit[slot])
        self._slot_commit[slot] = 0
        self._page_table[slot, :] = TRASH_PAGE
        self._table_dirty = True

    def _paged_preflight(self, active: list[int],
                         n_left: np.ndarray) -> np.ndarray:
        """Map / privatize every page the coming decode writes touch.

        For each row writing positions [pos, pos + n_left): allocate
        missing pages (commitment first), and privatize borrowed pages
        before the first write — copy-on-write when other holders remain,
        plain ownership takeover (unpublish) when this slot is the last.
        Rows that cannot get a page are clamped to the last mapped
        position (pool counts the stall); with page_reserve="worst"
        stalls are impossible by construction.  Ends by refreshing the
        device table."""
        assert self.pool is not None
        pool, ps = self.pool, self.page_size
        for i in active:
            steps = int(n_left[i])
            if steps <= 0:
                continue
            pos = int(self.slot_pos[i])
            pages = self._slot_pages[i]
            borrowed = self._slot_shared[i]
            for j in range(pos // ps, (pos + steps - 1) // ps + 1):
                phys = pages.get(j)
                if phys is None:
                    new = self._alloc_page(i)
                    if new is None:
                        n_left[i] = max(j * ps - pos, 0)
                        break
                    pages[j] = new
                    self._page_table[i, j] = new
                    self._table_dirty = True
                elif j in borrowed:
                    if pool.refcount(phys) > 1:
                        new = self._alloc_page(i)
                        if new is None:
                            n_left[i] = max(j * ps - pos, 0)
                            break
                        self.cache = self._page_copier(
                            self.cache, jnp.int32(phys), jnp.int32(new))
                        pool.release(phys)
                        pool.cow_copies += 1
                        pages[j] = new
                        self._page_table[i, j] = new
                    else:
                        # sole holder: take ownership; stop advertising
                        # the original tokens (content will diverge)
                        pool.unpublish(phys)
                    borrowed.discard(j)
                    self._table_dirty = True
        self._sync_table()
        return n_left

    def decode_k_headroom(self, k: int) -> int:
        """Clamp a decode quantum to free-page headroom: the largest
        k' <= k whose worst-case new-page demand across decodable rows
        the pool can satisfy right now.  Never below 1 — the per-row
        preflight clamps (and counts) rows a single step cannot map.
        Dense engines return k unchanged; the SLO scheduler calls this
        before sizing a quantum so memory pressure shrinks quanta instead
        of surfacing as mid-quantum stalls."""
        if not self.paged or k <= 1:
            return max(int(k), 1)
        assert self.pool is not None
        ps = self.page_size
        rows = []
        for i, req in enumerate(self.slot_req):
            if req is None or i in self._prefill:
                continue
            need = req.max_new_tokens + 1 - len(req.output)
            room = self.max_len - 1 - int(self.slot_pos[i])
            rows.append((int(self.slot_pos[i]),
                         max(1, min(need, room)),
                         self._slot_pages[i]))
        free = self.pool.free_pages
        best = 1
        for kk in range(1, int(k) + 1):
            demand = 0
            for pos, budget, pages in rows:
                steps = min(kk, budget)
                demand += sum(
                    1 for j in range(pos // ps, (pos + steps - 1) // ps + 1)
                    if j not in pages)
            if demand > free:
                break
            best = kk
        return best

    # ------------------------------------------------------------------
    # Occupancy telemetry
    # ------------------------------------------------------------------
    @property
    def cache_valid_tokens(self) -> int:
        """Tokens resident on behalf of live requests (prefilled plus
        decoded positions across occupied slots)."""
        total = 0
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            st = self._prefill.get(i)
            total += st.done if st is not None else int(self.slot_pos[i])
        return total

    @property
    def cache_resident_tokens(self) -> int:
        """Token capacity the cache actually holds resident: dense rows
        pin slots * max_len regardless of occupancy; paged residency is
        allocated pages only, with shared pages counted once — the
        dedup win prefix sharing buys."""
        if self.paged:
            assert self.pool is not None
            return self.pool.used_pages * self.page_size
        return self.slots * self.max_len

    @property
    def cache_utilization(self) -> float:
        """Peak valid tokens / peak resident token capacity.  Dense
        engines divide by the pinned slots * max_len; paged engines by
        the page high-water mark — and because shared pages are resident
        once but valid for every holder, prefix sharing can push this
        past 1.0 (that IS the dedup win)."""
        cap = (self.pool.peak_used * self.page_size
               if self.paged and self.pool is not None
               else self.slots * self.max_len)
        return self.peak_cache_tokens / cap if cap else 0.0

    def _note_occupancy(self) -> None:
        self.peak_active_slots = max(self.peak_active_slots,
                                     self.active_slots)
        self.peak_cache_tokens = max(self.peak_cache_tokens,
                                     self.cache_valid_tokens)

    @property
    def page_stats(self) -> dict:
        """Pool counters for benches / cluster metrics ({} when dense)."""
        if not self.paged:
            return {}
        assert self.pool is not None
        p = self.pool
        return {"page_size": self.page_size, "total_pages": p.total,
                "used_pages": p.used_pages, "peak_used": p.peak_used,
                "committed": p.committed, "shared_hits": p.shared_hits,
                "cow_copies": p.cow_copies, "stalls": p.stalls,
                "conflicts": p.conflicts,
                "published": p.published_pages}

    def _prefill_schedule(self, n: int, start: int = 0) -> collections.deque:
        """Chunk sizes for an ``n``-token prompt: fixed-size full chunks
        plus a power-of-two tail bucket (padded up), split further if the
        padding would write past ``max_len``.  Every size is a power of
        two <= ``prefill_chunk_len``, so the compiled-prefill shape set
        is the bucket table, never the prompt-length distribution.
        ``start`` skips tokens already resident (shared prefix pages):
        the schedule covers [start, n) only."""
        out: collections.deque = collections.deque()
        done = start
        c = self.prefill_chunk_len
        while n - done >= c:
            out.append(c)
            done += c
        rem = n - done
        while rem:
            b = _next_pow2(rem)
            if done + b <= self.max_len:
                out.append(b)                  # padded tail bucket
                break
            out.append(b // 2)                 # largest pow2 < rem, all real
            done += b // 2
            rem -= b // 2
        return out

    def admit_request(self, req: Request, *, drain: bool = False) -> bool:
        """Reserve a slot for ``req`` and queue its prefill chunks WITHOUT
        executing them — callers meter prefill by pumping
        :meth:`prefill_step` (runtimes interleave it with decode quanta).
        ``drain=True`` additionally pumps queued chunks (FIFO) until this
        request's first token is out — the synchronous convenience path
        for tests/examples (the old ``add_request``).

        Returns False when no slot is free (retry later).  Raises
        ``ValueError`` for prompts the cache row cannot hold — empty, or
        ``len(prompt) >= max_len`` (a clamped row write would silently
        corrupt the cache); such a request must be dropped, not retried.

        With ``chunked_prefill=False`` the whole prompt prefills here,
        monolithically and per-exact-length (the reference path)."""
        n = len(req.prompt)
        if n < 1 or n >= self.max_len:
            self.rejected_invalid += 1
            raise ValueError(
                f"prompt length {n} outside [1, {self.max_len - 1}]: the "
                f"cache row holds max_len={self.max_len} positions and "
                "needs at least one free for decode")
        slot = self._free_slot()
        if slot is None:
            return False
        start, row = 0, self._empty_row
        if self.paged:
            admitted = self._paged_admit(req, slot, n)
            if admitted is None:
                return False     # pool cannot commit (counted as conflict)
            start, row = admitted
        self.slot_req[slot] = req
        self.slot_pos[slot] = n
        if self.chunked_prefill:
            self._prefill[slot] = _PrefillState(
                req=req, row_cache=row,
                schedule=self._prefill_schedule(n, start), done=start)
            self._note_occupancy()
            if drain:
                while not req.output:
                    self.prefill_step()
            return True
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, row_cache = self._prefill_one(self.params, toks,
                                              self._empty_row)
        if self.paged:
            self.cache = self._paged_row_writer(
                self.cache, row_cache, jnp.int32(slot),
                jnp.asarray(self._write_table(slot)))
            self._publish_slot_pages(slot, req)
        else:
            self.cache = self._row_writer(self.cache, row_cache,
                                          jnp.int32(slot))
        # veltair: ignore[host-sync-in-hot-path] the ONE sanctioned sync per (monolithic) admission: the prompt's first sampled token
        first = int(jnp.argmax(logits[0]))      # prompt's first sampled token
        self.host_syncs += 1
        self.tokens_decoded += 1
        self.prefill_tokens += n
        self._note_occupancy()
        req.output.append(first)
        return True

    @property
    def prefill_pending(self) -> int:
        """Slots whose prompts are not fully prefilled yet."""
        return len(self._prefill)

    @property
    def decode_ready(self) -> bool:
        """Any occupied slot past prefill (eligible for decode quanta)."""
        return any(r is not None and i not in self._prefill
                   for i, r in enumerate(self.slot_req))

    def prefill_queue(self) -> list[tuple[int, int, int]]:
        """Slots mid-prefill, FIFO order: (slot, rid, chunks_left).  The
        SLO scheduler's view of the prefill backlog — it picks the slot
        whose TTFT deadline is tightest instead of the oldest one."""
        return [(slot, st.req.rid, len(st.schedule))
                for slot, st in self._prefill.items()]

    def decode_backlog(self) -> list[tuple[int, int, int]]:
        """Decodable slots: (slot, rid, tokens_left).  ``tokens_left`` is
        the remaining decode budget (the SRPT/slack estimate the SLO
        scheduler sizes decode quanta from)."""
        out = []
        for i, req in enumerate(self.slot_req):
            if req is None or i in self._prefill:
                continue
            need = req.max_new_tokens + 1 - len(req.output)
            room = self.max_len - 1 - int(self.slot_pos[i])
            out.append((i, req.rid, max(1, min(need, room))))
        return out

    def should_prefill(self, last_was_prefill: bool) -> bool:
        """Strict prefill/decode alternation (shared by both runtimes):
        spend this quantum on a prefill chunk when a prompt is
        mid-prefill and either nothing is decodable yet or the previous
        quantum was a decode — admissions are metered without starving
        co-resident decodes, and a long prompt steals at most every
        other quantum."""
        return bool(self._prefill) and (not self.decode_ready
                                        or not last_was_prefill)

    def prefill_step(self, slot: int | None = None) -> PrefillQuantum | None:
        """Run ONE prefill chunk — the prefill-side dispatch quantum —
        for ``slot``, or for the oldest slot still prefilling (FIFO)
        when ``slot`` is None.  SLO schedulers pass the slot whose TTFT
        deadline is tightest; FIFO callers pass nothing.

        The chunk prefills into the slot's accumulating batch-1 row cache
        at its start-position offset; only the final chunk pays a
        device->host sync (the first-token argmax) and writes the row
        into the batched cache, making the slot decodable.  Returns what
        ran, or None when nothing is prefilling."""
        if not self._prefill:
            return None
        if slot is None:
            slot, st = next(iter(self._prefill.items()))
        else:
            st = self._prefill[slot]
        c = st.schedule.popleft()
        n = len(st.req.prompt)
        valid = min(c, n - st.done)
        toks = np.zeros(c, np.int32)
        toks[:valid] = st.req.prompt[st.done:st.done + valid]
        traces0 = self.version_cache.traces
        t0 = time.perf_counter()
        logits, st.row_cache = self._prefill_chunk(
            self.params, jnp.asarray(toks)[None], st.row_cache,
            jnp.int32(st.done), jnp.int32(valid))
        st.done += valid
        self.prefill_chunks += 1
        self.prefill_tokens += valid
        self.prefill_pad_tokens += c - valid
        finished = not st.schedule
        if finished:
            if self.paged:
                self.cache = self._paged_row_writer(
                    self.cache, st.row_cache, jnp.int32(slot),
                    jnp.asarray(self._write_table(slot)))
                self._publish_slot_pages(slot, st.req)
            else:
                self.cache = self._row_writer(self.cache, st.row_cache,
                                              jnp.int32(slot))
            # veltair: ignore[host-sync-in-hot-path] the ONE sanctioned sync per admission (finishing chunk only)
            first = int(jnp.argmax(logits[0]))   # the ONE sync per admission
            # only the finishing chunk syncs, so only it yields a usable
            # wall time (intermediate chunks are async dispatches whose
            # device work this sync may still be draining — keying the
            # observation by the full prompt's pow2 bucket keeps walls
            # comparable); the trace guard drops first-visit compiles
            # like the decode path
            if traces0 == self.version_cache.traces:
                self.counter_bank.observe(
                    "prefill", _next_pow2(max(st.done, 1)),
                    self._entry.key, time.perf_counter() - t0,
                    tokens=valid, co_runners=self.co_runner_load)
            self.host_syncs += 1
            self.tokens_decoded += 1
            st.req.output.append(first)
            del self._prefill[slot]
        self._note_occupancy()
        return PrefillQuantum(slot=slot, rid=st.req.rid, chunk=c,
                              tokens=valid, finished=finished)

    def add_request(self, req: Request) -> bool:
        """Deprecated alias for ``admit_request(req, drain=True)``.

        Chunked and monolithic admission produce token-identical
        requests; chunked just runs through the bucket table."""
        warnings.warn(
            "ServingEngine.add_request is deprecated; use "
            "admit_request(req, drain=True) (or admit_request + "
            "prefill_step to meter prefill as scheduled quanta)",
            DeprecationWarning, stacklevel=2)
        return self.admit_request(req, drain=True)

    def step(self) -> list[Request]:
        """One decode step for every active slot; returns finished reqs.
        Slots still mid-prefill are not decodable and are skipped.

        Thin wrapper over the unified quantum path: a per-step dispatch
        is a 1-step non-fused quantum (one sync, one token per row)."""
        return self.finish_quantum(self.begin_quantum(1, fused=False))

    # ------------------------------------------------------------------
    # Fused dispatch quanta
    # ------------------------------------------------------------------
    def begin_quantum(self, k: int, *,
                      fused: bool = True) -> QuantumHandle | None:
        """Dispatch up to ``k`` decode steps for every active slot,
        without syncing.  This is THE decode entry point: :meth:`step`
        and :meth:`step_quantum` are thin wrappers over it.

        With ``fused=True`` the quantum runs as ONE fused on-device
        executable.  Per-row budgets (``n_left``) clamp each slot to its
        remaining token/length allowance and to ``k``; rows past their
        budget freeze on device (token, position and cache), so the
        result is token-for-token identical to ``k`` sequential
        :meth:`step` calls.  The executed quantum is capped at the
        largest K-bucket — callers dispatching bigger quanta issue
        further calls with the leftover (one sync each).

        With ``fused=False`` one plain decode step is dispatched (``k``
        is ignored beyond being positive) — the per-step reference path,
        kept on the same handle protocol so both modes do identical
        bookkeeping in :meth:`finish_quantum`.  Returns ``None`` when no
        slot is active (slots still mid-prefill are not decodable)."""
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and i not in self._prefill]
        if not active or k <= 0:
            return None
        n_left = np.zeros(self.slots, np.int32)
        toks = np.zeros(self.slots, np.int32)
        for i in active:
            req = self.slot_req[i]
            need = req.max_new_tokens + 1 - len(req.output)
            room = self.max_len - 1 - int(self.slot_pos[i])
            # a live row always decodes at least one step — exactly what
            # sequential step() does before its finish check, and it keeps
            # degenerate admissions (max_new_tokens=0, prompt at the length
            # limit) finishing instead of spinning with a zero budget
            n_left[i] = max(1, min(need, room))
            toks[i] = req.output[-1]
        if fused and self._spec_enabled:
            handle = self._try_spec_quantum(int(k), active, n_left.copy(),
                                            toks)
            if handle is not None:
                return handle
            # no usable draft / no room for the d+1 write span: the plain
            # fused quantum below is the per-row fallback
        if self.paged:
            cap = (1 if not fused else
                   min(int(k), self.quantum_buckets[-1]))
            n_left = self._paged_preflight(active,
                                           np.minimum(n_left, cap))
            if not any(n_left[i] > 0 for i in active):
                return None      # every decodable row waits on a free page
        if not fused:
            # per-slot positions: each row decodes at its own absolute
            # position and attends under its own kv-valid horizon, so
            # mixed-length / staggered prompts stay exact (free slots
            # compute garbage rows that the next admission's pristine-row
            # prefill replaces)
            traces0 = self.version_cache.traces
            t0 = time.perf_counter()
            logits, self.cache = self._decode(
                self.params, {"tokens": jnp.asarray(toks)}, self.cache,
                jnp.asarray(self.slot_pos))
            n_left = np.minimum(n_left, 1)
            return QuantumHandle(block=jnp.argmax(logits, axis=-1)[None],
                                 n_left=n_left, steps=1, active=active,
                                 t0=t0, traces0=traces0, bucket=1,
                                 tiles=self._entry.key)
        steps = int(min(int(k), int(n_left.max()),
                        self.quantum_buckets[-1]))
        bucket = next(b for b in self.quantum_buckets if b >= steps)
        n_left = np.minimum(n_left, steps)
        qfn = self.version_cache.quantum(self._entry, bucket, self.params,
                                         self.cache, self.slots)
        # timestamp AFTER the executable lookup: a cold K-bucket's AOT
        # compile is host-side cost the runtimes charge, not device work
        # the measured counters may attribute to interference
        traces0 = self.version_cache.traces
        t0 = time.perf_counter()
        block, self.cache, _ = qfn(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(self.slot_pos), jnp.asarray(n_left))
        self.quantum_calls += 1
        return QuantumHandle(block=block, n_left=n_left, steps=steps,
                             active=active, t0=t0, traces0=traces0,
                             bucket=bucket, tiles=self._entry.key)

    def _try_spec_quantum(self, k: int, active: list[int],
                          n_left: np.ndarray,
                          toks: np.ndarray) -> QuantumHandle | None:
        """Dispatch one speculative verify quantum, or return None to
        fall back to the plain fused quantum (no usable draft anywhere,
        a row too close to the cache end for the static d+1 write span,
        or — on paged engines — not enough free-page headroom for the
        worst-case d+1 writes per row).  The fallback never retraces:
        both paths run warmed executables."""
        d = self.spec_depth
        # the verify writes positions [pos, pos + d] for every active row
        # regardless of acceptance, so every row needs d steps of room
        if any(self.max_len - 1 - int(self.slot_pos[i]) < d
               for i in active):
            self.spec_fallbacks += 1
            return None
        if self.paged and self.decode_k_headroom(d + 1) < d + 1:
            # free-page headroom clamps the draft depth; with a static
            # depth that clamp IS the fallback to plain quanta
            self.spec_fallbacks += 1
            return None
        drafts = np.zeros((self.slots, d), np.int32)
        n_drafted = 0
        for i in active:
            req = self.slot_req[i]
            dr = self.drafter.draft(
                np.concatenate([np.asarray(req.prompt, np.int32),
                                np.asarray(req.output, np.int32)]), d)
            if dr is not None:
                drafts[i] = dr
                n_drafted += 1
        if n_drafted == 0:
            # adversarial (low-hit-rate) traffic: a verify forward would
            # emit one token per row for d+1 positions of compute — the
            # plain quantum is strictly better, so take it
            self.spec_fallbacks += 1
            return None
        cap = min(max(int(k), 1), d + 1, self.quantum_buckets[-1])
        n_left = np.minimum(n_left, cap)
        if self.paged:
            span = np.zeros(self.slots, np.int32)
            for i in active:
                span[i] = d + 1
            span = self._paged_preflight(active, span)
            # writes past a row's mapped span land on the trash page;
            # tokens whose KV lives there must never be emitted
            n_left = np.minimum(n_left, span)
            if not any(n_left[i] > 0 for i in active):
                self.spec_fallbacks += 1
                return None
        bucket = next(b for b in self.quantum_buckets if b >= cap)
        sfn = self.version_cache.spec_quantum(
            self._entry, bucket, d, self.params, self.cache, self.slots)
        traces0 = self.version_cache.traces
        t0 = time.perf_counter()
        block, n_emit, accepted, self.cache, _ = sfn(
            self.params, jnp.asarray(toks), jnp.asarray(drafts),
            self.cache, jnp.asarray(self.slot_pos), jnp.asarray(n_left))
        self.quantum_calls += 1
        self.spec_quanta += 1
        self.tokens_drafted += d * len(active)
        # steps=1: a verify quantum is ONE sequence-parallel forward —
        # that is the whole speedup — so virtual clocks charge it like a
        # single decode step while it emits up to min(k, d+1) tokens/row
        return QuantumHandle(block=block, n_left=n_left, steps=1,
                             active=active, t0=t0, traces0=traces0,
                             bucket=bucket, tiles=self._entry.key,
                             kind="spec", emitted=n_emit,
                             accepted=accepted, drafted=d)

    def finish_quantum(self, handle: QuantumHandle | None) -> list[Request]:
        """Block on a dispatched quantum — the single device->host sync at
        the quantum boundary — and do the request bookkeeping: append each
        row's tokens, advance positions, free finished slots.  Returns
        finished requests (like :meth:`step`); per-request executed steps
        land in ``handle.row_steps``."""
        if handle is None:
            return []
        if handle.kind == "spec":
            # ONE fused sync for the whole spec quantum: token block plus
            # per-row emission/acceptance come back in a single
            # device->host transfer instead of three serialized ones
            # veltair: ignore[host-sync-in-hot-path] THE sanctioned per-quantum sync (spec path: fused triple)
            block, emitted, accepted = jax.device_get(
                (handle.block, handle.emitted, handle.accepted))
            block = np.asarray(block)
            emitted = np.asarray(emitted).astype(np.int32)
            accepted = np.asarray(accepted)
            # fold the actual per-row emission into n_left so every
            # consumer below (and in the runtimes) sees real token counts
            handle.n_left = emitted
        else:
            # veltair: ignore[host-sync-in-hot-path] THE sanctioned per-quantum sync (one block transfer per quantum, PR 4)
            block = np.asarray(handle.block)
        self.host_syncs += 1
        if handle.kind == "spec":
            d = handle.drafted
            for i in handle.active:
                self.tokens_accepted += max(int(emitted[i]) - 1, 0)
                if int(accepted[i]) < d:
                    self.spec_rollbacks += 1
            if handle.active:
                mean = float(emitted[handle.active].sum()) \
                    / len(handle.active)
                self._spec_accept_ewma = (0.8 * self._spec_accept_ewma
                                          + 0.2 * mean)
        # measured counters: the sync above closed the quantum's device
        # span; observe it unless it was untimed or traced mid-span (a
        # first-visit compile inside the timed region must not read as
        # interference slowdown — the trace guard drops it).  Speculative
        # quanta observe under their own kind: their wall/token ratio
        # varies with acceptance, and folding them into "decode" floors
        # would read as phantom interference slowdown
        if handle.t0 > 0.0 and \
                handle.traces0 == self.version_cache.traces:
            self.counter_bank.observe(
                handle.kind, handle.bucket, handle.tiles,
                time.perf_counter() - handle.t0,
                tokens=int(handle.n_left.sum()),
                co_runners=self.co_runner_load)
        finished = []
        for i in handle.active:
            req = self.slot_req[i]
            took = int(handle.n_left[i])
            req.output.extend(int(t) for t in block[:took, i])
            self.slot_pos[i] += took
            self.tokens_decoded += took
            handle.row_steps[req.rid] = took
        self._note_occupancy()               # peak before finished rows free
        for i in handle.active:
            req = self.slot_req[i]
            if len(req.output) >= req.max_new_tokens + 1 or \
                    self.slot_pos[i] >= self.max_len - 1:
                req.done = True
                finished.append(req)
                self.release_slot(i)
        return finished

    def step_quantum(self, k: int) -> list[Request]:
        """Fused ``k``-step decode with exactly one host sync: dispatch +
        collect in one call (use :meth:`begin_quantum` /
        :meth:`finish_quantum` to overlap several engines)."""
        return self.finish_quantum(self.begin_quantum(k))

    def run_to_completion(self, reqs: list[Request],
                          max_steps: int = 10_000, *,
                          fused: bool = True) -> list[Request]:
        """Serve ``reqs`` to completion.  Decode runs on the fused
        quantum path by default (largest warmed K-bucket per dispatch,
        one sync each); ``fused=False`` keeps the per-token reference
        loop.  Both produce identical token streams."""
        pending = collections.deque(reqs)
        done: list[Request] = []
        k = self.quantum_buckets[-1] if fused else 1
        steps = 0
        while (pending or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            while pending and self.admit_request(pending[0]):
                pending.popleft()
            while self._prefill:        # drain queued chunks before decode
                self.prefill_step()
            done.extend(self.finish_quantum(self.begin_quantum(
                k, fused=fused)))
            steps += 1
        return done
