"""Batched serving engine (real JAX execution path).

Wraps a model's prefill/decode with continuous batching over request
slots: requests join free slots, prefill fills their cache rows, decode
steps run the whole batch, finished rows free their slots.  This is the
engine the examples drive on CPU with reduced models; at pod scale the
same functions are jitted with the serve-mode shardings (launch/serve.py).

The VELTAIR integration point: ``set_interference_level`` switches the
active kernel tile overrides (repro.kernels.dispatch) to the version the
adaptive compiler selected — the engine is oblivious to how the level was
derived.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 16
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 256, greedy: bool = True):
        self.cfg = cfg
        self.model: Model = build_model(cfg)
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self.cache = self.model.init_cache(batch_slots, max_len)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self._decode = jax.jit(self.model.decode_step)
        self._prefill_one = jax.jit(
            lambda p, toks, cache: build_model(cfg).prefill(
                p, {"tokens": toks}, cache))

    # ------------------------------------------------------------------
    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    @staticmethod
    def _batch_axis(path) -> int:
        """Scanned block caches carry a leading layer axis: batch is axis 1
        under the 'blocks' subtree, axis 0 elsewhere."""
        return 1 if any(getattr(p, "key", None) == "blocks"
                        for p in path) else 0

    def _slice_row(self, slot: int):
        return jax.tree_util.tree_map_with_path(
            lambda p, c: jax.lax.slice_in_dim(c, slot, slot + 1,
                                              axis=self._batch_axis(p)),
            self.cache)

    def _write_row(self, row_cache, slot: int):
        def put(p, c, r):
            ax = self._batch_axis(p)
            idx = [slice(None)] * c.ndim
            idx[ax] = slice(slot, slot + 1)
            return c.at[tuple(idx)].set(r.astype(c.dtype))
        return jax.tree_util.tree_map_with_path(put, self.cache, row_cache)

    def add_request(self, req: Request) -> bool:
        """Admit a request: prefill its prompt into its slot's cache rows.

        Single-row prefill runs on a batch-1 view then writes the slot row
        (slot caches are independent along the batch axis)."""
        slot = self._free_slot()
        if slot is None:
            return False
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, row_cache = self._prefill_one(self.params, toks,
                                              self._slice_row(slot))
        self.cache = self._write_row(row_cache, slot)
        first = int(jnp.argmax(logits[0]))
        req.output.append(first)
        self.slot_req[slot] = req
        self.slot_pos[slot] = len(req.prompt)
        return True

    def step(self) -> list[Request]:
        """One decode step for every active slot; returns finished reqs."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        toks = np.zeros(self.slots, np.int32)
        for i in active:
            toks[i] = self.slot_req[i].output[-1]
        # homogeneous decode position: engine steps slots in lockstep using
        # the max position; per-slot kv_valid masking keeps rows exact when
        # positions align (examples use aligned prompts).
        t = int(self.slot_pos[active].max())
        logits, self.cache = self._decode(
            self.params, {"tokens": jnp.asarray(toks)}, self.cache,
            jnp.int32(t))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for i in active:
            req = self.slot_req[i]
            req.output.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if len(req.output) >= req.max_new_tokens + 1 or \
                    self.slot_pos[i] >= self.max_len - 1:
                req.done = True
                finished.append(req)
                self.slot_req[i] = None
        return finished

    def run_to_completion(self, reqs: list[Request],
                          max_steps: int = 10_000) -> list[Request]:
        pending = list(reqs)
        done: list[Request] = []
        steps = 0
        while (pending or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            done.extend(self.step())
            steps += 1
        return done
