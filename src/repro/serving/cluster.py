"""Multi-model co-location runtime: N real engines, one unit pool.

This is the piece that turns the repo from "simulator + single-model
demo" into a multi-tenant serving system: a :class:`ClusterRuntime` runs
N concurrent :class:`~repro.serving.engine.ServingEngine`\\ s serving
*different* model architectures (e.g. gemma-2b next to starcoder2-3b
next to mamba2-780m, each a reduced real JAX model), partitions
``hw.n_units`` across them every scheduling quantum through the shared
:class:`~repro.core.allocator.UnitPool`, and drives a **per-engine**
interference level through each engine's precompiled
:class:`~repro.serving.version_cache.VersionCache`.

The paper's runtime loop, on the real execution path:

1. **Sense** — for each engine (the "victim"), synthesize a
   :class:`~repro.core.interference.CounterSample` from the live slot
   occupancy of its co-resident engines (what the performance counters
   would read) — :func:`~repro.core.interference.read_counters`.
2. **Estimate** — the policy maps the counter sample to a pressure
   estimate through its calibrated
   :class:`~repro.core.interference.LinearProxy`
   (``Policy.interference_from_counters``).  Ground-truth demand sums
   are never consulted online; they only exist inside the counter
   synthesizer and the offline calibration pass.
3. **Plan** — ``Policy.plan_chunk_at`` forms the next layer-block at
   that pressure (Alg. 2/3): the block's size becomes the engine's
   *dispatch quantum* (decode steps until the next scheduling
   intervention) and its unit requirement becomes the engine's share of
   the pool — so adaptive granularity, not just adaptive compilation,
   governs the real JAX path.  Baselines plug into the same loop:
   model-wise FCFS re-plans once per model pass, fixed-block every K
   steps, PREMA runs exclusively one quantum at a time.
4. **Act** — the engine's grant is (re)allocated work-conservingly from
   the pool and ``set_interference_level`` swaps the engine to the code
   version compiled for the estimated pressure (a dictionary swap of
   precompiled executables after :meth:`ClusterRuntime.warmup`).
5. **Dispatch** — in fused mode (default) each granted engine's whole
   quantum runs as ONE on-device executable
   (:meth:`~repro.serving.engine.ServingEngine.begin_quantum`), and the
   tick issues every engine's quantum *before* blocking on any of them
   (:meth:`~repro.serving.engine.ServingEngine.finish_quantum`), so
   co-located engines' device work overlaps instead of serializing
   through Python — one host sync per engine per quantum.

Time: a virtual clock advances ``step_dt`` per executed decode step —
in fused mode a tick spans the longest quantum it dispatched, and
completions inside a quantum keep exact per-step virtual finish times.
``wall_clock=True`` charges measured wall time instead (version-switch
stalls included, as in ``OnlineRuntime``).  ``fused=False`` restores the
per-step dispatch loop (the measured baseline).
"""
from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

from repro.core import cost_model as cm
from repro.core.allocator import UnitPool
from repro.core.interference import RunningDemand, read_counters
from repro.core.layer_block import ModelPlan
from repro.core.qos import QueryRecord, ServingMetrics, TierSpec, summarize
from repro.core.scheduler import Policy, TaskState
from repro.serving.engine import ServingEngine, Request
from repro.serving.request import synth_prompts
from repro.serving.runtime import Workload, plan_demand
from repro.serving.slo import AdmissionController, DeadlineBook, pick_quantum
from repro.serving.tenants import cluster_plans


@dataclasses.dataclass
class EngineTenant:
    """One co-located tenant: a real engine plus its analytic plan.

    ``engine`` executes the (reduced) JAX model; ``plan`` is the
    compile-time artifact the scheduler reasons with (version tables,
    QoS slices, ``Avg_C``) — the same pairing the single-engine
    ``OnlineRuntime`` uses, replicated per model.  ``tier`` is the
    tenant's SLO tier (core.qos.TIER_ORDER); a Workload's ``tiers`` map
    overrides it per serve, and None means untiered legacy behavior."""
    name: str
    engine: ServingEngine
    plan: ModelPlan
    tier: str | None = None


@dataclasses.dataclass
class _TenantState:
    """Mutable per-tenant serving state (grants, quanta, queues)."""
    pending: collections.deque = dataclasses.field(
        default_factory=collections.deque)
    grant: int = 0                 # units currently held from the pool
    quantum_left: int = 0          # decode steps before the next re-plan
    cursor: int = 0                # layer-block cursor into plan.layers
    oldest_admit: float = 0.0      # head-of-line admit time (priority)
    levels: list = dataclasses.field(default_factory=list)
    quanta: int = 0                # re-plan count
    busy: float = 0.0              # occupancy-weighted unit-time
    alloc: float = 0.0             # granted unit-time
    records: list = dataclasses.field(default_factory=list)
    prefill_last: bool = False     # prefill/decode alternation state
    prefill_quanta: int = 0        # prefill chunks dispatched
    ttft: dict = dataclasses.field(default_factory=dict)   # rid -> ttft


@dataclasses.dataclass
class ClusterMetrics:
    """Co-location serve result: aggregate + per-tenant ServingMetrics,
    plus the scheduling traces the tests/benchmarks assert on."""
    aggregate: ServingMetrics
    per_tenant: dict[str, ServingMetrics]
    level_traces: dict[str, list[float]]     # per-quantum engine levels
    partition_trace: list[dict[str, int]]    # per-tick unit grants
    quanta: dict[str, int]                   # re-plan counts
    pool_conflicts: int                      # grants below QoS minimum
    pool_peak_used: int
    host_syncs: dict[str, int] = dataclasses.field(default_factory=dict)
    tokens_per_sync: dict[str, float] = dataclasses.field(
        default_factory=dict)
    prefill_quanta: dict[str, int] = dataclasses.field(default_factory=dict)
    page_stats: dict[str, dict] = dataclasses.field(default_factory=dict)
                                             # per-tenant KV page-pool
                                             # counters ({} on dense engines)
    spec_stats: dict[str, dict] = dataclasses.field(default_factory=dict)
                                             # per-tenant speculative-decode
                                             # counters (zeros on non-spec
                                             # engines)

    @property
    def mean_levels(self) -> dict[str, float]:
        return {n: float(np.mean(tr)) if tr else 0.0
                for n, tr in self.level_traces.items()}


def build_cluster(archs: list[str], hw: cm.HardwareSpec, *,
                  batch_slots: int = 2, max_len: int = 32,
                  qos_scale: float = 3.0, seed: int = 0,
                  plans: dict[str, ModelPlan] | None = None,
                  tiers: dict[str, str] | None = None,
                  page_size: int | None = None,
                  n_pages: int | None = None,
                  page_reserve: str = "worst",
                  ) -> list[EngineTenant]:
    """Stand up one reduced real engine per architecture.

    Each engine gets its own params, KV/SSM cache, version cache, and —
    through ``version_sets`` from its *own* plan — its own
    adaptive-compiled tile table, so per-engine levels select per-model
    code versions."""
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model

    plans = plans or cluster_plans(list(archs), hw, qos_scale=qos_scale)
    out = []
    for i, arch in enumerate(archs):
        cfg = get_reduced_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed + i))
        engine = ServingEngine(cfg, params, batch_slots=batch_slots,
                               max_len=max_len,
                               version_sets=plans[arch].version_sets,
                               page_size=page_size, n_pages=n_pages,
                               page_reserve=page_reserve)
        out.append(EngineTenant(name=arch, engine=engine, plan=plans[arch],
                                tier=(tiers or {}).get(arch)))
    return out


class ClusterRuntime:
    """Admission/partition/dispatch loop over N co-located real engines.

    Knobs: ``step_dt`` (virtual seconds per decode tick),
    ``wall_clock`` (charge measured step+switch wall time instead),
    ``max_steps`` (tick budget), ``seed`` (counter-read noise).  The
    policy instance is shared — it is the *global* scheduler regulating
    all tenants, exactly as in the paper; per-engine behavior differs
    because each engine's counter read sees different co-runners."""

    def __init__(self, tenants: list[EngineTenant], policy: Policy,
                 hw: cm.HardwareSpec, *, step_dt: float = 1e-3,
                 wall_clock: bool = False, max_steps: int = 200_000,
                 seed: int = 0, fused: bool = True,
                 scheduler: str = "slo",
                 admission: AdmissionController | None = None,
                 tiers: dict[str, TierSpec] | None = None,
                 counter_source: str = "oracle",
                 refit_proxy: bool | None = None):
        if len({t.name for t in tenants}) != len(tenants):
            raise ValueError("tenant names must be unique")
        if scheduler not in ("slo", "fifo"):
            raise ValueError(f"scheduler must be 'slo' or 'fifo', "
                             f"got {scheduler!r}")
        if counter_source not in ("oracle", "measured"):
            raise ValueError(f"counter_source must be 'oracle' or "
                             f"'measured', got {counter_source!r}")
        self.tenants = list(tenants)
        self.policy = policy
        self.hw = hw
        self.step_dt = step_dt
        self.wall_clock = wall_clock
        self.max_steps = max_steps
        self.fused = fused
        self.scheduler = scheduler
        self.admission = admission       # None = admit everything (legacy)
        self.book = DeadlineBook(tiers)
        # counter provenance per engine: "measured" reads each tenant's
        # own per-quantum wall-time bank (oracle fallback while cold);
        # refit_proxy=None turns the online RLS re-fit on exactly when
        # serving on measured counters
        self.counter_source = counter_source
        self.refit_proxy = (counter_source == "measured"
                            if refit_proxy is None else bool(refit_proxy))
        self.counter_sources = collections.Counter()  # source label -> polls
        self.pool = UnitPool(hw.n_units)
        self.ticks = 0
        self.conflicts = 0               # admission rejections (engine full)
        self.tenant_conflicts = {t.name: 0 for t in self.tenants}
        self.shed = 0                    # rejected by admission control
        self.deferred = 0                # admissions delayed by it
        self.tenant_shed = {t.name: 0 for t in self.tenants}
        self.tenant_deferred = {t.name: 0 for t in self.tenants}
        self.sched_trace: list[tuple] = []  # (tenant, "prefill", rid,
                                            #  tier, t) |
                                            # (tenant, "decode", (rids...), t)
        self.outputs: dict[int, list[int]] = {}   # rid -> served tokens
        self.compile_time_s = 0.0        # wall time inside level switches
        self.partition_trace: list[dict[str, int]] = []
        self._rng = np.random.default_rng(seed)
        self._state = {t.name: _TenantState() for t in self.tenants}
        self._demand_cache: dict[tuple[str, int], tuple] = {}

    # ------------------------------------------------------------------
    def warmup(self, prompt_lens: tuple[int, ...] = (),
               quantum_buckets: tuple[int, ...] | None = None) -> dict:
        """AOT-compile every engine's full level table AND its fused
        K-bucket quantum executables (level switches during serve()
        become dictionary swaps; the first fused dispatch never traces).
        Returns per-tenant version-cache stats."""
        return {t.name: t.engine.warmup(prompt_lens=prompt_lens,
                                        quantum_buckets=quantum_buckets)
                for t in self.tenants}

    def tenant_prompts(self, wl: Workload) -> dict[str, np.ndarray]:
        """Per-tenant prompt tables for ``wl`` — seeded per tenant
        position, so co-located tenants never replay byte-identical
        prompt streams, while staying deterministic per (workload seed,
        cluster layout).  ``wl.shared_prefix_len`` gives every prompt of
        a tenant the same opening run (a per-tenant system prompt) —
        on paged engines the prefix index deduplicates those pages
        across the tenant's co-resident requests."""
        out = {}
        for idx, t in enumerate(self.tenants):
            tbl = synth_prompts(wl.n_queries, wl.prompt_len,
                                t.engine.cfg.vocab_size, wl.seed + idx)
            if wl.shared_prefix_len > 0:
                spl = min(wl.shared_prefix_len, tbl.shape[1])
                pre = np.random.default_rng(
                    wl.seed + idx + 0x9EF1).integers(
                    0, t.engine.cfg.vocab_size, spl)
                tbl[:, :spl] = pre.astype(tbl.dtype)
            out[t.name] = tbl
        return out

    def _footprint(self, tenant: EngineTenant, units: int) -> tuple:
        key = (tenant.name, units)
        hit = self._demand_cache.get(key)
        if hit is None:
            hit = plan_demand(tenant.plan, self.hw, max(1, units))
            self._demand_cache[key] = hit
        return hit

    def _live_demands(self, meta: dict, now: float) -> list[RunningDemand]:
        """One RunningDemand per occupied slot across all engines — the
        live-occupancy picture the counter synthesizer reads.  Slot
        footprints are evaluated at the engine's current grant (fair
        share before its first grant)."""
        fair = max(1, self.hw.n_units // max(len(self.tenants), 1))
        out = []
        for idx, t in enumerate(self.tenants):
            st = self._state[t.name]
            bw, cache, ici = self._footprint(t, st.grant or fair)
            for req in t.engine.slot_req:
                if req is None:
                    continue
                _, _, admit = meta[req.rid]
                horizon = admit + self.step_dt * (req.max_new_tokens + 1)
                out.append(RunningDemand(
                    tenant=idx, bw=bw, cache=cache, ici=ici, start=admit,
                    finish=max(horizon, now + self.step_dt)))
        return out

    def _task(self, idx: int, tenant: EngineTenant) -> TaskState:
        st = self._state[tenant.name]
        return TaskState(tid=idx, tenant=tenant.name, plan=tenant.plan,
                         arrival=st.oldest_admit, next_layer=st.cursor)

    def _release(self, st: _TenantState) -> None:
        if st.grant:
            self.pool.release(st.grant)
            st.grant = 0
        st.quantum_left = 0

    # ------------------------------------------------------------------
    def _replan(self, idx: int, tenant: EngineTenant,
                active_tasks: list[TaskState],
                demands: list[RunningDemand], now: float) -> None:
        """One scheduling quantum decision for ``tenant``: counters ->
        proxy -> layer-block plan -> pool grant + engine code version."""
        st = self._state[tenant.name]
        sample = read_counters(self.hw, idx, demands, now, self._rng,
                               source=self.counter_source,
                               bank=tenant.engine.counter_bank)
        self.counter_sources[sample.source] += 1
        if self.refit_proxy:
            target = (sample.truth if sample.truth is not None
                      else tenant.engine.counter_bank.pressure())
            if target is not None:
                self.policy.observe_counters(sample, target)
        itf = self.policy.interference_from_counters(sample)
        task = self._task(idx, tenant)
        plan = self.policy.plan_chunk_at(task, active_tasks, itf, now,
                                         self.pool.free)
        if plan is None:
            return
        if plan.exclusive and self.pool.used > 0:
            return                        # temporal policy: wait for idle
        desired = max(1, min(plan.units, self.hw.n_units))
        lo = max(1, min(plan.units_min, desired))
        if not plan.allow_partial:
            if self.pool.free < desired:
                return                    # all-or-nothing: stall this tick
            grant = self.pool.try_alloc(desired)
        else:
            grant = self.pool.try_alloc_range(lo, desired)
            if grant == 0:
                return                    # pool exhausted: stall this tick
        st.grant = grant
        st.quantum_left = max(plan.end_layer - task.next_layer, 1)
        st.cursor = plan.end_layer % tenant.plan.n_layers
        st.quanta += 1
        level = self.policy.level_from_counters(sample)
        t0 = time.perf_counter()
        tenant.engine.set_interference_level(level)
        self.compile_time_s += time.perf_counter() - t0
        st.levels.append(level)

    # ------------------------------------------------------------------
    def serve(self, wl: Workload) -> ClusterMetrics:
        """Replay ``wl`` through the co-located engines.  Arrival tenant
        names must match EngineTenant names (each query runs on its own
        model's engine)."""
        by_name = {t.name: t for t in self.tenants}
        unknown = {name for _, name in wl.arrivals} - set(by_name)
        if unknown:
            raise KeyError(f"workload tenants {sorted(unknown)} have no "
                           f"engine; cluster serves {sorted(by_name)}")
        lens = wl.prompt_lengths()
        prompts = self.tenant_prompts(wl)
        arrivals = collections.deque(
            (at, name, rid) for rid, (at, name)
            in enumerate(sorted(wl.arrivals)))
        meta: dict[int, tuple[str, float, float]] = {}
        rejected: set[int] = set()
        deferred_rids: set[int] = set()
        by_tenant_name = {t.name: t for t in self.tenants}
        now = 0.0

        def tier_of(name: str) -> str | None:
            # the workload's tiers map wins; the tenant's own tier is the
            # standing assignment; None = untiered legacy
            wt = wl.tier_of(name)
            return wt if wt is not None else by_tenant_name[name].tier

        tiered = any(tier_of(t.name) is not None for t in self.tenants)

        def admit(t: EngineTenant) -> None:
            st = self._state[t.name]
            while st.pending:
                at, rid = st.pending[0]
                req = Request(rid=rid,
                              prompt=prompts[t.name][rid, :lens[rid]],
                              max_new_tokens=wl.max_new_tokens,
                              tier=tier_of(t.name))
                if self.scheduler == "slo" and self.admission is not None:
                    entry = self.book.entry(rid)
                    pages_needed, pages_free = t.engine.admission_pages(
                        req.prompt, wl.max_new_tokens)
                    decision = self.admission.decide(
                        now=now, entry=entry,
                        spec=self.book.spec(entry.tier),
                        step_dt=self.step_dt,
                        own_chunks=len(
                            t.engine._prefill_schedule(lens[rid])),
                        own_decode_steps=wl.max_new_tokens,
                        backlog_chunks=sum(
                            c for _, _, c in t.engine.prefill_queue()),
                        slot_free=t.engine.active_slots < t.engine.slots,
                        pages_needed=pages_needed, pages_free=pages_free)
                    if decision == "shed":
                        self.shed += 1
                        self.tenant_shed[t.name] += 1
                        self.book.drop(rid)
                        st.pending.popleft()
                        continue
                    if decision == "defer":
                        if rid not in deferred_rids:
                            deferred_rids.add(rid)
                            self.deferred += 1
                            self.tenant_deferred[t.name] += 1
                        break
                try:
                    admitted = t.engine.admit_request(req)
                except ValueError:
                    # inadmissible prompt length: hard conflict, drop it
                    if rid not in rejected:
                        rejected.add(rid)
                        self.conflicts += 1
                        self.tenant_conflicts[t.name] += 1
                    st.pending.popleft()
                    continue
                if not admitted:
                    if rid not in rejected:       # QoS conflict, once/query
                        rejected.add(rid)
                        self.conflicts += 1
                        self.tenant_conflicts[t.name] += 1
                    break
                meta[rid] = (t.name, at, now)
                if req.output:                    # monolithic admission
                    st.ttft[rid] = now - at
                st.pending.popleft()
            active = [meta[r.rid][2] for r in t.engine.slot_req
                      if r is not None]
            st.oldest_admit = min(active) if active else now

        def tenant_deadline(name: str) -> float:
            """Earliest deadline across a tenant's in-flight and pending
            requests — the slack key grants are ordered by when tiered."""
            t = by_tenant_name[name]
            rids = [r.rid for r in t.engine.slot_req if r is not None]
            rids += [rid for _, rid in self._state[name].pending]
            dls = [self.book.entry(r).deadline for r in rids
                   if self.book.get(r) is not None]
            return min(dls) if dls else float("inf")

        while arrivals or any(self._state[t.name].pending
                              or t.engine.active_slots
                              for t in self.tenants):
            if self.ticks >= self.max_steps:
                break
            while arrivals and arrivals[0][0] <= now:
                at, name, rid = arrivals.popleft()
                self.book.register(rid, name, tier_of(name), at,
                                   by_name[name].plan.qos_s)
                self._state[name].pending.append((at, rid))
            for t in self.tenants:
                admit(t)

            active = [t for t in self.tenants if t.engine.active_slots]
            if not active:
                if arrivals:                 # idle: jump to next arrival
                    now = max(now, arrivals[0][0])
                    continue
                break

            # grants of engines that drained their slots go back first
            for t in self.tenants:
                if not t.engine.active_slots:
                    self._release(self._state[t.name])

            # stamp each engine's live co-runner occupancy so its measured
            # counter bank records who it shared the machine with
            total_active = sum(t.engine.active_slots for t in self.tenants)
            for t in self.tenants:
                t.engine.co_runner_load = total_active - t.engine.active_slots

            t_tick = time.perf_counter()
            demands = self._live_demands(meta, now)
            active_tasks = [self._task(i, t)
                            for i, t in enumerate(self.tenants)
                            if t.engine.active_slots]
            need = [task for task in active_tasks
                    if self._state[task.tenant].grant == 0]
            if self.scheduler == "slo" and tiered:
                # tiered serve: grants go out in earliest-deadline order
                # (the engine whose tightest query has least slack plans
                # first, so it gets units before the pool runs dry)
                ordered = sorted(
                    need, key=lambda task: (tenant_deadline(task.tenant),
                                            task.arrival, task.tid))
            else:
                ordered = self.policy.order_pending(need, now)
            for task in ordered:
                self._replan(task.tid, self.tenants[task.tid],
                             active_tasks, demands, now)

            self.partition_trace.append(
                {t.name: self._state[t.name].grant for t in self.tenants})

            # dispatch phase: issue every granted engine's quantum BEFORE
            # blocking on any of them — in fused mode begin_quantum returns
            # without a host sync, so N co-located engines' device work
            # overlaps instead of serializing through the Python loop
            granted = [t for t in active if self._state[t.name].grant > 0]
            # lockstep tick quantum: every granted engine dispatches the
            # same number of steps this tick (the smallest outstanding
            # quantum), so no co-runner loses virtual time waiting for a
            # longer quantum to drain — engines with bigger blocks keep
            # their grant and continue next tick
            q_tick = min((self._state[t.name].quantum_left
                          for t in granted), default=0)
            launched: list[tuple] = []
            for t in active:
                st = self._state[t.name]
                if st.grant == 0:
                    # stalled this tick (pool exhausted / exclusive quantum
                    # pending); time still advances below, so the next tick
                    # re-plans instead of spinning
                    continue
                # per-engine prefill/decode pick.  FIFO: strict
                # alternation — an engine with a prompt mid-prefill
                # spends every other quantum (or every quantum, if
                # nothing is decodable) on one prefill chunk, so
                # admissions are metered without starving its decodes.
                # SLO: earliest-deadline pick over the engine's prefill
                # queue and decode backlog (TTFT-urgent chunks preempt).
                pf_slot = None
                k_dispatch = q_tick
                if self.scheduler == "slo":
                    pick = pick_quantum(t.engine, self.book, now,
                                        self.step_dt, max(q_tick, 1))
                    do_prefill = pick is not None and pick[0] == "prefill"
                    if do_prefill:
                        pf_slot = pick[1]
                    elif pick is not None:
                        k_dispatch = min(q_tick, pick[1]) or 1
                else:
                    do_prefill = t.engine.should_prefill(st.prefill_last)
                    st.prefill_last = do_prefill
                if do_prefill:
                    occupancy = 1.0 / t.engine.slots   # the prefilling row
                    pf = t.engine.prefill_step(pf_slot)
                    st.prefill_quanta += 1
                    if pf is not None:
                        e = self.book.get(pf.rid)
                        self.sched_trace.append(
                            (t.name, "prefill", pf.rid,
                             e.tier if e is not None else None, now))
                    launched.append((t, st, None, occupancy, pf))
                    continue
                # decode occupancy: slots still mid-prefill are skipped by
                # the decode quantum and must not be charged as busy
                occupancy = (t.engine.active_slots
                             - t.engine.prefill_pending) / t.engine.slots
                handle = (t.engine.begin_quantum(k_dispatch)
                          if self.fused else None)
                if handle is not None:
                    self.sched_trace.append((t.name, "decode", tuple(
                        t.engine.slot_req[i].rid for i in handle.active),
                        now))
                launched.append((t, st, handle, occupancy, None))

            # collect phase: one host sync per engine per quantum
            finished: list[tuple[str, Request, int]] = []
            prefill_done: list[tuple[_TenantState, int]] = []
            held: list[tuple] = []
            max_run = 1
            for t, st, handle, occupancy, pf in launched:
                if pf is not None:
                    fin = []
                    steps = 1
                    row_steps = {}
                    row_tokens = 1.0          # the one row being prefilled
                    if pf.finished:
                        prefill_done.append((st, pf.rid))
                elif self.fused:
                    fin = t.engine.finish_quantum(handle)
                    steps = handle.steps if handle is not None else 1
                    row_steps = (handle.row_steps if handle is not None
                                 else {})
                    row_tokens = (float(handle.n_left.sum())
                                  if handle is not None else 0.0)
                else:
                    fin = t.engine.step()
                    steps = 1
                    row_steps = {}
                    row_tokens = occupancy * t.engine.slots
                max_run = max(max_run, steps)
                held.append((st, st.grant, occupancy, steps, row_tokens,
                             t.engine.slots))
                for req in fin:
                    # row_steps is in tokens; a speculative quantum emits
                    # several per sync, so the finish offset is capped at
                    # the quantum's clock steps
                    finished.append((t.name, req,
                                     min(row_steps.get(req.rid, steps),
                                         steps)))
                st.quantum_left -= steps
                if st.quantum_left <= 0 or not t.engine.active_slots:
                    self._release(st)

            dt = (time.perf_counter() - t_tick) if self.wall_clock \
                else self.step_dt * max_run
            self.ticks += 1
            t_begin = now
            now += dt
            # unit-time accounting uses the same dt basis as the clock, so
            # summarize()'s avg_units/efficiency stay consistent in both
            # virtual and wall_clock modes.  In virtual mode an engine is
            # charged for the steps it actually executed; busy counts the
            # rows that actually decoded (grant * step_dt * row-steps /
            # slots reduces to the old grant * dt * occupancy at steps=1)
            for st, grant, occupancy, steps, row_tokens, slots in held:
                if self.wall_clock:
                    st.busy += grant * dt * occupancy
                    st.alloc += grant * dt
                else:
                    st.busy += grant * self.step_dt * row_tokens / slots
                    st.alloc += grant * self.step_dt * steps
            for st, rid in prefill_done:
                st.ttft[rid] = now - meta[rid][1]
            for name, req, off in finished:
                _, at, _ = meta[req.rid]
                st = self._state[name]
                fin = now if self.wall_clock else t_begin + off * self.step_dt
                entry = self.book.get(req.rid)
                has_tier = tier_of(name) is not None
                st.records.append(QueryRecord(
                    tenant=name, arrival=at, finish=fin,
                    qos_s=by_name[name].plan.qos_s,
                    ttft_s=st.ttft.get(req.rid),
                    tier=(entry.tier if has_tier and entry is not None
                          else "standard"),
                    deadline=(entry.deadline
                              if has_tier and entry is not None else None)))
                self.outputs[req.rid] = list(req.output)
                self.book.drop(req.rid)

        for t in self.tenants:               # return whatever is still held
            self._release(self._state[t.name])

        span = max((wl.arrivals[-1][0] if wl.arrivals else 0.0), 1e-9)
        per_tenant = {}
        all_records: list[QueryRecord] = []
        busy = alloc = 0.0
        peak_tokens = peak_cap = 0
        for t in self.tenants:
            st = self._state[t.name]
            n_t = sum(1 for _, name in wl.arrivals if name == t.name)
            eng = t.engine
            per_tenant[t.name] = summarize(
                st.records, n_t / span,
                self.tenant_conflicts[t.name] / max(n_t, 1),
                st.busy, st.alloc,
                shed=self.tenant_shed[t.name],
                deferred=self.tenant_deferred[t.name],
                peak_cache_tokens=eng.peak_cache_tokens,
                cache_utilization=eng.cache_utilization,
                tokens_accepted=eng.tokens_accepted,
                draft_hit_rate=eng.draft_hit_rate,
                spec_rollbacks=eng.spec_rollbacks)
            all_records.extend(st.records)
            busy += st.busy
            alloc += st.alloc
            peak_tokens += eng.peak_cache_tokens
            peak_cap += (eng.pool.peak_used * eng.page_size
                         if eng.paged and eng.pool is not None
                         else eng.slots * eng.max_len)
        drafted = sum(t.engine.tokens_drafted for t in self.tenants)
        accepted = sum(t.engine.tokens_accepted for t in self.tenants)
        aggregate = summarize(all_records, wl.qps,
                              self.conflicts / max(wl.n_queries, 1),
                              busy, alloc,
                              shed=self.shed, deferred=self.deferred,
                              peak_cache_tokens=peak_tokens,
                              cache_utilization=(peak_tokens / peak_cap
                                                 if peak_cap else 0.0),
                              proxy_rms_error=self.policy.proxy_rms_error,
                              refit_count=self.policy.proxy_refits,
                              tokens_accepted=accepted,
                              draft_hit_rate=accepted / max(drafted, 1),
                              spec_rollbacks=sum(t.engine.spec_rollbacks
                                                 for t in self.tenants))
        return ClusterMetrics(
            aggregate=aggregate, per_tenant=per_tenant,
            level_traces={t.name: list(self._state[t.name].levels)
                          for t in self.tenants},
            partition_trace=list(self.partition_trace),
            quanta={t.name: self._state[t.name].quanta
                    for t in self.tenants},
            pool_conflicts=self.pool.conflicts,
            pool_peak_used=self.pool.peak_used,
            host_syncs={t.name: t.engine.host_syncs
                        for t in self.tenants},
            tokens_per_sync={t.name: t.engine.tokens_per_sync
                             for t in self.tenants},
            prefill_quanta={t.name: self._state[t.name].prefill_quanta
                            for t in self.tenants},
            page_stats={t.name: t.engine.page_stats
                        for t in self.tenants},
            spec_stats={t.name: t.engine.spec_stats
                        for t in self.tenants})
