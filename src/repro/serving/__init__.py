"""Serving layer: three entry points over the same scheduling core.

* ``repro.serving.simulator`` — discrete-event simulator (analytical
  latencies, real scheduling decisions; paper-figure experiments);
* ``repro.serving.runtime`` — single shared real JAX engine with the
  policy's proxy-driven level in the loop (``OnlineRuntime``);
* ``repro.serving.cluster`` — N co-located real engines with different
  models, per-quantum unit partitioning, per-engine levels
  (``ClusterRuntime``).

See docs/ARCHITECTURE.md for the paper-to-code map.
"""
from repro.serving.simulator import SimConfig, Simulator, run_sweep
from repro.serving.request import (diurnal_workload, gamma_poisson_workload,
                                   poisson_workload, qos_inverse_weights,
                                   synth_prompts, uniform_workload)
from repro.serving.runtime import (OnlineRuntime, Workload, plan_demand,
                                   replay_through_simulator)
from repro.serving.slo import (AdmissionController, DeadlineBook, SloEntry,
                               pick_quantum)
from repro.serving.cluster import (ClusterMetrics, ClusterRuntime,
                                   EngineTenant, build_cluster)
from repro.serving.tenants import (build_paper_plans, cluster_plan,
                                   cluster_plans, engine_version_sets,
                                   lm_serving_plans)
from repro.serving.engine import (PREFILL_CHUNK_LEN, QUANTUM_BUCKETS,
                                  PrefillQuantum, QuantumHandle,
                                  ServingEngine)
from repro.serving.paging import TRASH_PAGE, PagePool
from repro.serving.version_cache import VersionCache, VersionEntry, tiles_key
from repro.core.counters import CounterBank, QuantumObservation

__all__ = [
    "SimConfig", "Simulator", "run_sweep", "poisson_workload",
    "gamma_poisson_workload", "diurnal_workload",
    "qos_inverse_weights", "uniform_workload", "synth_prompts",
    "OnlineRuntime", "Workload", "plan_demand", "replay_through_simulator",
    "AdmissionController", "DeadlineBook", "SloEntry", "pick_quantum",
    "ClusterMetrics", "ClusterRuntime", "EngineTenant", "build_cluster",
    "build_paper_plans", "cluster_plan", "cluster_plans",
    "engine_version_sets", "lm_serving_plans",
    "PREFILL_CHUNK_LEN", "QUANTUM_BUCKETS", "PrefillQuantum",
    "QuantumHandle", "ServingEngine",
    "TRASH_PAGE", "PagePool",
    "VersionCache", "VersionEntry", "tiles_key",
    "CounterBank", "QuantumObservation",
]
