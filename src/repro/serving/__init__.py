from repro.serving.simulator import SimConfig, Simulator, run_sweep
from repro.serving.request import (poisson_workload, qos_inverse_weights,
                                   synth_prompts, uniform_workload)
from repro.serving.runtime import (OnlineRuntime, Workload, plan_demand,
                                   replay_through_simulator)
from repro.serving.tenants import (build_paper_plans, engine_version_sets,
                                   lm_serving_plans)
from repro.serving.version_cache import VersionCache, VersionEntry, tiles_key

__all__ = [
    "SimConfig", "Simulator", "run_sweep", "poisson_workload",
    "qos_inverse_weights", "uniform_workload", "synth_prompts",
    "OnlineRuntime", "Workload", "plan_demand", "replay_through_simulator",
    "build_paper_plans", "engine_version_sets", "lm_serving_plans",
    "VersionCache", "VersionEntry", "tiles_key",
]
