from repro.serving.simulator import SimConfig, Simulator, run_sweep
from repro.serving.request import (poisson_workload, qos_inverse_weights,
                                   uniform_workload)
from repro.serving.tenants import build_paper_plans, lm_serving_plans

__all__ = [
    "SimConfig", "Simulator", "run_sweep", "poisson_workload",
    "qos_inverse_weights", "uniform_workload", "build_paper_plans",
    "lm_serving_plans",
]
