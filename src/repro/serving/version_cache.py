"""Precompiled multi-version executable cache for the serving engine.

VELTAIR's premise is that switching code versions under interference
pressure is cheap enough to do *online*.  A naive engine pays a full
``jax.jit`` retrace every time the tile overrides change — exactly the
overhead adaptive compilation is supposed to amortize.  This module makes
the switch a dictionary lookup: one :class:`VersionEntry` per tile
configuration holds its own jitted prefill/decode callables, traced under
a :func:`repro.kernels.dispatch.tile_context` that bakes that entry's
tiles into the executable.  Because every entry owns its trace cache,

  * revisiting a level never retraces (jit hits its own cache);
  * multiple engines with different active versions coexist in one
    process — no engine's switch can invalidate another's compiled code,
    since nothing reads the process-global override table at trace time.

Keys are derived from the same tile dictionaries the engine's level
tables produce (``VersionSet`` selections or ``DEFAULT_LEVEL_TILES``), so
the cache holds at most one entry per distinct code version (<= NUM_LEVELS
per engine).  Memory footprint: one traced+compiled prefill per prompt
length warmed plus one decode executable per entry, one chunked-prefill
executable per (entry, chunk bucket) — the serving admission path, which
is why mixed-length traffic never retraces after warmup — plus one fused
quantum-decode executable per (entry, K-bucket) actually used and, for
speculative engines, one verify executable per (entry, K-bucket,
draft-depth).

Donation: the decode and quantum executables donate their cache argument
(``donate_argnums``), so every step updates the KV/SSM buffers in place
instead of allocating a fresh cache — the caller must treat the cache it
passed in as consumed and adopt the returned one.  The prefill callable
deliberately does NOT donate: the engine reuses one pristine cache row
for every admission, and donating it would invalidate that row after the
first prefill.

Paged engines need no special handling here: the per-slot ``page_table``
rides *inside* the cache pytree, so every executable this cache holds is
keyed on the paged layout's shapes (pool + table) exactly like any other
cache leaf — a dense and a paged engine of the same model simply trace
distinct executables, and :meth:`ServingEngine.warmup` prebuilds the
paged gather/scatter helpers alongside these entries.

``traces`` counts *actual* jax traces (the counter increments inside the
traced body, so it fires on first-call tracing and any shape-driven
retrace, and stays flat on cache hits) — tests assert a full level sweep
after :meth:`ServingEngine.warmup` leaves it unchanged.
"""
from __future__ import annotations

import dataclasses
import operator
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels import dispatch


def tiles_key(tiles: dict[str, dict]) -> tuple:
    """Canonical hashable key for an op -> tiling-kwargs table."""
    return tuple(sorted(
        (op, tuple(sorted(kw.items()))) for op, kw in tiles.items()))


class StaticArgError(TypeError):
    """A static compile-key argument (K-bucket, draft depth) is not a
    hashable integer from the sanctioned bucket space.  Raised eagerly
    at the :class:`VersionCache` boundary: an unhashable or unbucketed
    key would otherwise silently trace + AOT-compile a fresh executable
    per distinct value — the exact retrace hazard the static analyzer's
    ``retrace-hazard`` rule guards at the call sites."""


def _static_int(name: str, v: Any, minimum: int = 1) -> int:
    """Validate a static compile key: a plain integer (no bools, no
    floats, nothing unhashable) of at least ``minimum``."""
    if isinstance(v, bool):
        raise StaticArgError(
            f"{name} must be a plain int compile key, got bool {v!r}")
    try:
        i = operator.index(v)
    except TypeError:
        raise StaticArgError(
            f"{name} must be a hashable int compile key, got "
            f"{type(v).__name__} {v!r} — a non-int key would trace a "
            f"fresh executable per call") from None
    if i < minimum:
        raise StaticArgError(f"{name}={i} must be >= {minimum}")
    return i


def _pow2_bucket(name: str, v: Any) -> int:
    """Validate a K-bucket key: a power-of-two ``_static_int``."""
    i = _static_int(name, v)
    if i & (i - 1):
        raise StaticArgError(
            f"{name}={i} is not a power-of-two bucket — every distinct "
            f"unbucketed value compiles its own executable (the "
            f"zero-post-warmup-retrace contract); round up via "
            f"_next_pow2 or pick from the engine's quantum_buckets")
    return i


@dataclasses.dataclass
class VersionEntry:
    """One code version: jitted executables with the tiles baked in."""
    key: tuple
    tiles: dict[str, dict]
    prefill: Callable          # (params, tokens (1,L), row_cache) -> ...
    decode: Callable           # (params, {"tokens": (B,)}, cache, t) -> ...
    # bucketed prefill quantum: (params, tokens (1,C), row_cache,
    #   t0, valid_len) -> (logits, row_cache).  One trace per chunk
    #   bucket C — t0/valid_len are traced, so mixed-length traffic
    #   shares the bucket's executable instead of retracing per length.
    prefill_chunk: Callable = None
    # K-bucket -> AOT-compiled fused quantum decode
    #   (params, tokens (B,), cache, pos (B,), n_left (B,)) -> (block, cache, pos)
    quanta: dict[int, Callable] = dataclasses.field(default_factory=dict)
    # (K-bucket, draft depth) -> AOT-compiled speculative verify quantum
    #   (params, tokens (B,), drafts (B,d), cache, pos (B,), n_left (B,))
    #   -> (block (d+1,B), n_emit (B,), accepted (B,), cache, pos)
    spec: dict[tuple[int, int], Callable] = dataclasses.field(
        default_factory=dict)


class VersionCache:
    """tiles -> VersionEntry, building (and counting) on first use."""

    def __init__(self, model: Any):
        self.model = model
        self._entries: dict[tuple, VersionEntry] = {}
        self.hits = 0              # get() found an existing entry
        self.misses = 0            # get() had to build one
        self.traces = 0            # actual jax traces across all entries

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def stats(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "traces": self.traces}

    def warmup(self, tile_tables) -> list[VersionEntry]:
        """Pre-create one entry per tile table (``LadderSpec`` levels, a
        level-grid sweep, ...) so serve-time version switches are
        dictionary lookups.  Returns the entries in input order (the
        engine's warmup then executes each to force the actual
        compiles); duplicate tables resolve to the same entry."""
        return [self.get(tiles) for tiles in tile_tables]

    def get(self, tiles: dict[str, dict]) -> VersionEntry:
        if dispatch.get_mode() == "xla":
            # the reference path ignores tiling entirely: all versions
            # share one executable (keying by tiles here would trace
            # NUM_LEVELS byte-identical programs for nothing)
            tiles = {}
        key = tiles_key(tiles)
        entry = self._entries.get(key)
        if entry is None:
            entry = self._build(tiles, key)
            self._entries[key] = entry
            self.misses += 1
        else:
            self.hits += 1
        return entry

    # ------------------------------------------------------------------
    def _build(self, tiles: dict[str, dict], key: tuple) -> VersionEntry:
        snap = {op: dict(kw) for op, kw in tiles.items()}
        model = self.model

        # The tile_context wraps the *trace*: the body below runs as python
        # only while jax traces it (first call per shape), which is exactly
        # when kernels.dispatch reads the overrides.  Compiled re-runs never
        # enter it, so the process-global override table is irrelevant to
        # this entry's numerics — and the trace counter stays honest.
        def prefill(params, tokens, row_cache):
            self.traces += 1
            with dispatch.tile_context(snap):
                return model.prefill(params, {"tokens": tokens}, row_cache)

        def decode(params, inputs, cache, t):
            self.traces += 1
            with dispatch.tile_context(snap):
                return model.decode_step(params, inputs, cache, t)

        def prefill_chunk(params, tokens, row_cache, t0, valid_len):
            self.traces += 1
            with dispatch.tile_context(snap):
                return model.prefill_chunk(params, {"tokens": tokens},
                                           row_cache, t0, valid_len)

        # decode donates its cache (in-place KV/SSM update; the engine
        # adopts the returned cache every step); prefill and
        # prefill_chunk must NOT — their cache argument may be the
        # shared pristine row (see module docstring)
        return VersionEntry(key=key, tiles=snap, prefill=jax.jit(prefill),
                            decode=jax.jit(decode, donate_argnums=(2,)),
                            prefill_chunk=jax.jit(prefill_chunk))

    # ------------------------------------------------------------------
    def quantum(self, entry: VersionEntry, k: int, params: Any,
                cache: Any, batch: int) -> Callable:
        """The fused K-step decode executable for ``entry`` (built on
        first use, then cached on the entry).

        ``k`` is the static K-bucket; ``cache`` supplies the shapes to
        compile against (it is only read for shape/dtype here).  The
        executable is AOT-lowered and compiled against abstract shapes —
        warmup can pre-build every bucket without executing a single
        decode step — and donates the cache argument, so each of the K
        on-device steps updates the KV/SSM state in place.

        Raises :class:`StaticArgError` when ``k`` is not a hashable
        power-of-two int (unbucketed keys would silently compile one
        executable per distinct value)."""
        k = _pow2_bucket("k", k)
        fn = entry.quanta.get(k)
        if fn is not None:
            self.hits += 1
            return fn
        self.misses += 1
        snap = entry.tiles
        model = self.model

        def qfn(params, tokens, cache, pos, n_left):
            self.traces += 1
            with dispatch.tile_context(snap):
                return model.decode_quantum(params, tokens, cache, pos,
                                            n_left, k)

        vec = jax.ShapeDtypeStruct((int(batch),), jnp.int32)
        cache_sds = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
        fn = (jax.jit(qfn, donate_argnums=(2,))
              .lower(params, vec, cache_sds, vec, vec).compile())
        entry.quanta[k] = fn
        return fn

    # ------------------------------------------------------------------
    def spec_quantum(self, entry: VersionEntry, k: int, d: int,
                     params: Any, cache: Any, batch: int) -> Callable:
        """The speculative verify executable for ``entry``, keyed per
        (K-bucket, draft depth) — like :meth:`quantum`, AOT-lowered
        against abstract shapes so warmup pre-builds every reachable
        (bucket, depth) pair and serve-time level switches stay a dict
        swap with zero retraces.

        ``k`` statically caps the per-row emission budget (a spec
        quantum emits at most ``min(k, d+1)`` tokens per row); ``d`` is
        the static draft depth that fixes the (B, d+1) verify shape.

        Raises :class:`StaticArgError` for a non-pow2/unhashable ``k``
        or a non-int ``d`` (see :meth:`quantum`)."""
        k, d = _pow2_bucket("k", k), _static_int("d", d)
        fn = entry.spec.get((k, d))
        if fn is not None:
            self.hits += 1
            return fn
        self.misses += 1
        snap = entry.tiles
        model = self.model

        def sfn(params, tokens, drafts, cache, pos, n_left):
            self.traces += 1
            with dispatch.tile_context(snap):
                return model.verify_quantum(
                    params, tokens, drafts, cache, pos,
                    jnp.minimum(n_left, jnp.int32(k)))

        vec = jax.ShapeDtypeStruct((int(batch),), jnp.int32)
        mat = jax.ShapeDtypeStruct((int(batch), d), jnp.int32)
        cache_sds = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
        fn = (jax.jit(sfn, donate_argnums=(3,))
              .lower(params, vec, mat, cache_sds, vec, vec).compile())
        entry.spec[(k, d)] = fn
        return fn
