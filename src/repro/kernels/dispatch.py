"""Kernel dispatch: route hot-spot ops to XLA reference or Pallas kernels.

Modes:
  "xla"        pure-jnp reference path (default; used by the dry-run so
               cost_analysis sees clean XLA HLO)
  "interpret"  Pallas kernels in interpret mode (CPU correctness testing)
  "pallas"     compiled Pallas kernels (real TPU target)

The mode is process-global (set once at launch).  ``get_matmul`` always
returns a callable; ``get_attention``/``get_ssd`` return None in "xla" mode so
callers fall back to their inline reference math.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

_MODE = "xla"
_VALID = ("xla", "interpret", "pallas")

# Tile overrides installed by the adaptive-compilation layer (core.multiversion):
# maps op name -> dict of tiling kwargs for the Pallas kernels.
_TILE_OVERRIDES: dict[str, dict] = {}


def set_mode(mode: str) -> None:
    global _MODE
    if mode not in _VALID:
        raise ValueError(f"kernel mode {mode!r} not in {_VALID}")
    _MODE = mode


def get_mode() -> str:
    return _MODE


def set_tile_overrides(op: str, **kwargs) -> None:
    _TILE_OVERRIDES[op] = dict(kwargs)


def clear_tile_overrides() -> None:
    _TILE_OVERRIDES.clear()


def tile_overrides(op: str) -> dict:
    return dict(_TILE_OVERRIDES.get(op, {}))


def all_tile_overrides() -> dict[str, dict]:
    """Snapshot of every installed override (observability: the online
    runtime's tests assert the engine's level switches land here)."""
    return {op: dict(kw) for op, kw in _TILE_OVERRIDES.items()}


def _ref_matmul(x, w):
    return jnp.einsum("...m,mf->...f", x, w)


def get_matmul() -> Callable:
    if _MODE == "xla":
        return _ref_matmul
    from repro.kernels import ops
    interpret = _MODE == "interpret"

    def mm(x, w):
        return ops.block_matmul(x, w, interpret=interpret,
                                **tile_overrides("matmul"))
    return mm


def get_attention() -> Callable | None:
    if _MODE == "xla":
        return None
    from repro.kernels import ops
    interpret = _MODE == "interpret"

    def attn(q, k, v, *, q_positions, kv_valid_len, window, softcap):
        return ops.flash_attention(
            q, k, v, q_positions=q_positions, kv_valid_len=kv_valid_len,
            window=window, softcap=softcap, interpret=interpret,
            **tile_overrides("attention"))
    return attn


def get_ssd() -> Callable | None:
    if _MODE == "xla":
        return None
    from repro.kernels import ops
    interpret = _MODE == "interpret"

    def ssd(x, dt, a, b, c, *, chunk_size, initial_state=None):
        return ops.ssd_scan(x, dt, a, b, c, chunk_size=chunk_size,
                            initial_state=initial_state, interpret=interpret,
                            **tile_overrides("ssd"))
    return ssd
