"""Kernel dispatch: route hot-spot ops to XLA reference or Pallas kernels.

Modes:
  "xla"        pure-jnp reference path (default; used by the dry-run so
               cost_analysis sees clean XLA HLO)
  "interpret"  Pallas kernels in interpret mode (CPU correctness testing)
  "pallas"     compiled Pallas kernels (real TPU target)

The mode is process-global (set once at launch).  ``get_matmul`` always
returns a callable; ``get_attention``/``get_ssd`` return None in "xla" mode so
callers fall back to their inline reference math.

Tile overrides come from two sources, consulted in order:

  1. an active *override context* (``tile_context``) — a complete per-trace
     table pushed by whoever is tracing (the serving version cache bakes one
     into every cached executable, so multiple engines can hold different
     code versions alive in one process without fighting over globals);
  2. the process-global table (``install_tile_overrides`` /
     ``set_tile_overrides``) — the last level installed anywhere, kept for
     observability and for code paths that run outside a context.

A context is *atomic*: while one is active, ops it does not name have NO
override (the global table is not consulted), so switching tile sources can
never leave a stale per-op entry shaping kernels.
"""
from __future__ import annotations

import contextlib
import json
import pathlib
from typing import Callable, Iterator

import jax.numpy as jnp

_MODE = "xla"
_VALID = ("xla", "interpret", "pallas")

# Process-global tile overrides installed by the adaptive-compilation layer:
# maps op name -> dict of tiling kwargs for the Pallas kernels.
_TILE_OVERRIDES: dict[str, dict] = {}

# Stack of complete override tables pushed by tile_context (innermost last).
_CONTEXT_STACK: list[dict[str, dict]] = []

# Process-global autotuned level ladder (grid idx -> {op: tiling kwargs}),
# installed by load_ladder()/install_ladder().  Engines constructed without
# an explicit ladder or version_sets snapshot this table at build time and
# use it in place of their built-in DEFAULT_LEVEL_TILES.
_LADDER: list | None = None


def set_mode(mode: str) -> None:
    global _MODE
    if mode not in _VALID:
        raise ValueError(f"kernel mode {mode!r} not in {_VALID}")
    _MODE = mode


def get_mode() -> str:
    return _MODE


def set_tile_overrides(op: str, **kwargs) -> None:
    _TILE_OVERRIDES[op] = dict(kwargs)


def install_tile_overrides(tiles: dict[str, dict]) -> None:
    """Atomically replace the whole global table with ``tiles``.

    Unlike per-op ``set_tile_overrides`` this also *clears* ops absent from
    ``tiles`` — switching from a source that overrides {matmul, attention}
    to one that overrides only {matmul} must not leave the old attention
    entry behind."""
    _TILE_OVERRIDES.clear()
    for op, kw in tiles.items():
        _TILE_OVERRIDES[op] = dict(kw)


def clear_tile_overrides() -> None:
    _TILE_OVERRIDES.clear()


@contextlib.contextmanager
def tile_context(tiles: dict[str, dict]) -> Iterator[None]:
    """Scope a complete override table: inside the ``with``, every op reads
    from ``tiles`` only (ops it does not name get no override).  Used at
    trace time so each cached executable bakes in exactly one code version,
    independent of the process-global table."""
    _CONTEXT_STACK.append({op: dict(kw) for op, kw in tiles.items()})
    try:
        yield
    finally:
        _CONTEXT_STACK.pop()


def tile_overrides(op: str) -> dict:
    if _CONTEXT_STACK:
        return dict(_CONTEXT_STACK[-1].get(op, {}))
    return dict(_TILE_OVERRIDES.get(op, {}))


def all_tile_overrides() -> dict[str, dict]:
    """Snapshot of every installed override (observability: the online
    runtime's tests assert the engine's level switches land here)."""
    src = _CONTEXT_STACK[-1] if _CONTEXT_STACK else _TILE_OVERRIDES
    return {op: dict(kw) for op, kw in src.items()}


def install_ladder(levels: list | None) -> None:
    """Install (or clear, with None) the process-global autotuned level
    ladder.  ``levels`` is a per-grid-level list of op -> tiling-kwargs
    tables — the ``levels`` payload of a ``LadderSpec``.  Engines built
    afterwards snapshot it; engines already built are unaffected."""
    global _LADDER
    if levels is None:
        _LADDER = None
        return
    _LADDER = [{op: dict(kw) for op, kw in lvl.items()} for lvl in levels]


def active_ladder() -> list | None:
    """Deep copy of the installed ladder levels (None when none is)."""
    if _LADDER is None:
        return None
    return [{op: dict(kw) for op, kw in lvl.items()} for lvl in _LADDER]


def load_ladder(path) -> list:
    """Load a serialized LadderSpec JSON and install its levels as the
    process-global ladder.  Parses the raw JSON rather than importing
    the core dataclass (the kernel layer stays import-light); structural
    validation beyond the basics is LadderSpec.validate()'s job."""
    data = json.loads(pathlib.Path(path).read_text())
    levels = data.get("levels")
    if not isinstance(levels, list) or not levels or \
            not all(isinstance(lvl, dict) for lvl in levels):
        raise ValueError(f"{path}: not a serialized LadderSpec "
                         "(missing/malformed 'levels')")
    install_ladder(levels)
    return active_ladder()


def _ref_matmul(x, w):
    return jnp.einsum("...m,mf->...f", x, w)


def get_matmul() -> Callable:
    if _MODE == "xla":
        return _ref_matmul
    from repro.kernels import ops
    interpret = _MODE == "interpret"

    def mm(x, w):
        return ops.block_matmul(x, w, interpret=interpret,
                                **tile_overrides("matmul"))
    return mm


def get_attention() -> Callable | None:
    if _MODE == "xla":
        return None
    from repro.kernels import ops
    interpret = _MODE == "interpret"

    def attn(q, k, v, *, q_positions, kv_valid_len, window, softcap):
        return ops.flash_attention(
            q, k, v, q_positions=q_positions, kv_valid_len=kv_valid_len,
            window=window, softcap=softcap, interpret=interpret,
            **tile_overrides("attention"))
    return attn


def get_paged_attention() -> Callable | None:
    """Paged-decode attention (KV read through a scalar-prefetched page
    table).  None in "xla" mode — callers gather the pool through the
    table and fall back to reference attention.  Unlike dense attention
    there is no tile override: the page size fixes the kv block."""
    if _MODE == "xla":
        return None
    from repro.kernels import ops
    interpret = _MODE == "interpret"

    def attn(q, k_pool, v_pool, *, page_table, q_positions, kv_valid_len,
             window, softcap):
        return ops.flash_attention_paged(
            q, k_pool, v_pool, page_table=page_table,
            q_positions=q_positions, kv_valid_len=kv_valid_len,
            window=window, softcap=softcap, interpret=interpret)
    return attn


def get_ssd() -> Callable | None:
    if _MODE == "xla":
        return None
    from repro.kernels import ops
    interpret = _MODE == "interpret"

    def ssd(x, dt, a, b, c, *, chunk_size, initial_state=None):
        return ops.ssd_scan(x, dt, a, b, c, chunk_size=chunk_size,
                            initial_state=initial_state, interpret=interpret,
                            **tile_overrides("ssd"))
    return ssd
