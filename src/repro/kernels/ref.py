"""Pure-jnp oracles for every Pallas kernel (shape-for-shape reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("...k,kn->...n", x, w)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  offset, kv_valid_len, window: int | None = None,
                  softcap: float | None = None) -> jax.Array:
    """Same contract as kernels.flash_attention (query i at offset+i)."""
    from repro.models.layers import attend
    b, s = q.shape[:2]
    qpos = jnp.asarray(offset, jnp.int32) + jnp.arange(s, dtype=jnp.int32)
    qpos = jnp.broadcast_to(qpos[None], (b, s))
    return attend(q, k, v, q_positions=qpos, kv_valid_len=kv_valid_len,
                  window=window, softcap=softcap, use_kernel_hook=False)


def ssd_ref(x, dt, a, b, c, *, chunk_size, initial_state=None):
    from repro.models.ssm import ssd_reference
    return ssd_reference(x, dt, a, b, c, chunk_size=chunk_size,
                         initial_state=initial_state)
