"""Causal GQA flash attention Pallas kernel (online softmax).

Grid: (B, H, Sq/bq, T/bkv) with the KV axis innermost; running max /
denominator / fp32 output accumulator live in VMEM scratch and persist
across KV steps (TPU grid iteration is sequential).  Supports:

  * GQA/MQA: kv head = query head // (H/K)  (via BlockSpec index_map)
  * causal masking with a query position offset (decode: offset = t);
    offset may be per-batch-row (continuous batching decodes every slot
    at its own absolute position)
  * sliding-window masking (starcoder2 / recurrentgemma local attention)
  * kv_valid_len: cache slots beyond the valid length are masked
    (scalar or per-batch-row)
  * logit softcap (tanh)

The (bq, bkv) block shape is a locality/parallelism knob exposed to the
adaptive compiler alongside the matmul tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _flash_kernel(scalars_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  kv_steps: int, bq: int, bkv: int, scale: float,
                  window: int | None, softcap: float | None):
    bi = pl.program_id(0)
    offset = scalars_ref[0, bi]
    kv_valid = scalars_ref[1, bi]
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale       # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (bkv, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bkv)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = (k_pos <= q_pos) & (k_pos < kv_valid)
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                      # (bq,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ki == kv_steps - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bkv", "window", "softcap", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    offset, kv_valid_len, bq: int = 512, bkv: int = 512,
                    window: int | None = None, softcap: float | None = None,
                    interpret: bool = False) -> jax.Array:
    """q (B,S,H,D); k/v (B,T,K,D); query i of batch row b has absolute
    position offset[b]+i.

    offset / kv_valid_len may be traced int32 scalars or (B,) vectors
    (scalar-prefetched, broadcast to per-row).
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    bq = min(bq, s)
    bkv = min(bkv, t)
    # pad S and T to block multiples (extra kv masked via kv_valid_len logic;
    # extra q rows discarded after the call)
    sp = ((s + bq - 1) // bq) * bq
    tp = ((t + bkv - 1) // bkv) * bkv
    if sp != s:
        q = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    if tp != t:
        k = jnp.pad(k, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    kv_steps = tp // bkv
    off = jnp.broadcast_to(jnp.asarray(offset, jnp.int32).reshape(-1), (b,))
    kvl = jnp.broadcast_to(
        jnp.minimum(jnp.asarray(kv_valid_len, jnp.int32), t).reshape(-1),
        (b,))
    scalars = jnp.stack([off, kvl])                           # (2, B)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, sp // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d),
                         lambda bi, hi, qi, ki, sc: (bi, qi, hi, 0)),
            pl.BlockSpec((1, bkv, 1, d),
                         lambda bi, hi, qi, ki, sc: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, bkv, 1, d),
                         lambda bi, hi, qi, ki, sc: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d),
                               lambda bi, hi, qi, ki, sc: (bi, qi, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_flash_kernel, kv_steps=kv_steps, bq=bq, bkv=bkv,
                          scale=d ** -0.5, window=window, softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sp, h, d), q.dtype),
        interpret=interpret,
    )(scalars, q, k, v)
    return out[:, :s]


def _paged_flash_kernel(scalars_ref, table_ref, *rest, **kw):
    # the page table is consumed entirely by the KV BlockSpec index_maps;
    # the kernel body is the dense flash kernel (block ki IS logical page
    # ki, so its position arithmetic holds unchanged)
    return _flash_kernel(scalars_ref, *rest, **kw)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "interpret"))
def flash_attention_paged(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                          page_table: jax.Array, *, offset, kv_valid_len,
                          window: int | None = None,
                          softcap: float | None = None,
                          interpret: bool = False) -> jax.Array:
    """Decode flash attention reading KV through a per-slot page table.

    q (B,S,H,D) with small S (decode: 1); k/v pools (P, page_size, K, D)
    where P counts physical pages (index 0 is the pinned trash page);
    page_table (B, pages_per_slot) int32 maps each row's logical page to
    a physical one.  The table is the *second* scalar-prefetch operand —
    the KV BlockSpec index_map reads ``table[bi, ki]``, so each grid step
    DMAs exactly one physical page and the kv block size is the page
    size.  Unallocated entries point at trash; their garbage keys sit at
    logical positions >= kv_valid and are masked like any invalid slot.
    """
    b, s, h, d = q.shape
    ps_sz, kh = k_pool.shape[1], k_pool.shape[2]
    g = h // kh
    n_slot = page_table.shape[1]
    t = n_slot * ps_sz
    off = jnp.broadcast_to(jnp.asarray(offset, jnp.int32).reshape(-1), (b,))
    kvl = jnp.broadcast_to(
        jnp.minimum(jnp.asarray(kv_valid_len, jnp.int32), t).reshape(-1),
        (b,))
    scalars = jnp.stack([off, kvl])                           # (2, B)
    table = page_table.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, 1, n_slot),
        in_specs=[
            pl.BlockSpec((1, s, 1, d),
                         lambda bi, hi, qi, ki, sc, tb: (bi, qi, hi, 0)),
            pl.BlockSpec((1, ps_sz, 1, d),
                         lambda bi, hi, qi, ki, sc, tb: (tb[bi, ki], 0,
                                                         hi // g, 0)),
            pl.BlockSpec((1, ps_sz, 1, d),
                         lambda bi, hi, qi, ki, sc, tb: (tb[bi, ki], 0,
                                                         hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, 1, d),
                               lambda bi, hi, qi, ki, sc, tb: (bi, qi, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((s,), jnp.float32),
            pltpu.VMEM((s,), jnp.float32),
            pltpu.VMEM((s, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_flash_kernel, kv_steps=n_slot, bq=s,
                          bkv=ps_sz, scale=d ** -0.5, window=window,
                          softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), q.dtype),
        interpret=interpret,
    )(scalars, table, q, k_pool, v_pool)
