"""Jit'd wrappers around the Pallas kernels (the dispatch contract).

These adapt model-side calling conventions (leading batch dims, per-token
position arrays) to the kernels' layouts, and are what
``repro.kernels.dispatch`` routes to in "interpret"/"pallas" modes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import block_matmul as _bm
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd


def block_matmul(x: jax.Array, w: jax.Array, *, bm: int = 256, bk: int = 512,
                 bn: int = 256, interpret: bool = False) -> jax.Array:
    """x (..., K) @ w (K, N) with explicit VMEM tiling."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = _bm.block_matmul_2d(x2, w, bm=bm, bk=bk, bn=bn,
                              interpret=interpret)
    return out.reshape(*lead, w.shape[-1])


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_positions: jax.Array, kv_valid_len, window=None,
                    softcap=None, bq: int = 512, bkv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """Adapter: models pass q_positions (B,S); the kernel takes a per-row
    offset with query i of row b at offset[b]+i (all our call sites use
    row-contiguous positions — prefill offset 0, decode offset t[b], which
    differs per slot under continuous batching)."""
    offset = q_positions[..., 0].reshape(-1)  # per-row first-query position
    return _fa.flash_attention(q, k, v, offset=offset,
                               kv_valid_len=kv_valid_len, bq=bq, bkv=bkv,
                               window=window, softcap=softcap,
                               interpret=interpret)


def flash_attention_paged(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                          *, page_table: jax.Array, q_positions: jax.Array,
                          kv_valid_len, window=None, softcap=None,
                          interpret: bool = False) -> jax.Array:
    """Adapter for the page-table decode kernel: k/v are physical page
    pools (P, page_size, K, D) and ``page_table`` (B, pages_per_slot)
    maps each row's logical pages.  No tile knob — the page size IS the
    kv block size (one page per DMA), so adaptive tile tables don't
    shape this op."""
    offset = q_positions[..., 0].reshape(-1)
    return _fa.flash_attention_paged(q, k_pool, v_pool, page_table,
                                     offset=offset,
                                     kv_valid_len=kv_valid_len,
                                     window=window, softcap=softcap,
                                     interpret=interpret)


def ssd_scan(x, dt, a, b, c, *, chunk_size: int = 256, initial_state=None,
             interpret: bool = False):
    return _ssd.ssd_scan(x, dt, a, b, c, chunk_size=chunk_size,
                         initial_state=initial_state, interpret=interpret)
