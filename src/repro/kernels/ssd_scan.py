"""Mamba-2 SSD chunked-scan Pallas kernel.

Grid: (B, H, L/Q) with the chunk axis innermost.  The running SSD state
(P, N) lives in VMEM scratch and carries across chunk steps — TPU grid
iteration is sequential, so the inter-chunk recurrence needs no extra pass.
Per chunk the work is three small MXU matmuls ((Q,N)x(N,Q), (Q,Q)x(Q,P),
(N,Q)x(Q,P)): the "duality" that makes SSDs MXU-friendly.

The chunk size Q trades VMEM locality (larger intra-chunk matmuls, fewer
state round-trips) against parallel grid width — the SSD variant knob used
by the adaptive compiler for the mamba2/recurrentgemma cells.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                y_ref, state_ref, h_scratch, *, n_chunks: int, q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    a = a_ref[0]                                     # scalar decay rate (<0)
    bm = b_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)

    da = dt * a                                      # (Q,) log-decay
    seg = jnp.cumsum(da)                             # inclusive
    total = seg[-1]

    # intra-chunk (attention-like masked matmul)
    i_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    j_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.exp(seg[:, None] - seg[None, :])
    gate = jnp.where(j_pos <= i_pos, decay, 0.0)
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)   # (Q,Q)
    m_att = cb * gate * dt[None, :]
    y = jnp.dot(m_att, x, preferred_element_type=jnp.float32)    # (Q,P)

    # inter-chunk: y += exp(seg_i) * C_i . h_in   (h (P,N))
    h = h_scratch[...]
    y += jnp.exp(seg)[:, None] * jnp.dot(
        cm, h.T, preferred_element_type=jnp.float32)

    # state update: h' = exp(total) h + X^T (w * B),  w_j = exp(total-seg_j)dt_j
    w = jnp.exp(total - seg) * dt                    # (Q,)
    h_scratch[...] = jnp.exp(total) * h + jnp.dot(
        x.T, bm * w[:, None], preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _flush():
        state_ref[0, 0] = h_scratch[...]


@functools.partial(jax.jit, static_argnames=("chunk_size", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk_size: int = 256,
             initial_state: jax.Array | None = None,
             interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x (B,L,H,P); dt (B,L,H) fp32; a (H,) fp32; b/c (B,L,H,N).

    -> (y (B,L,H,P), final_state (B,H,P,N) fp32).  L is padded to a chunk
    multiple with dt=0 (exact: zero step contributes nothing, decay 1)."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk_size, l)
    orig_l = l
    if l % q:
        pad = q - l % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = x.shape[1]
    n_chunks = l // q
    h0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=n_chunks, q=q),
        grid=(bsz, h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, q, 1, n), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, 1, n), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt.astype(jnp.float32), a.astype(jnp.float32), b, c, h0)
    return y[:, :orig_l], state
