"""Tiled matmul Pallas kernel with parametric BlockSpec VMEM tiling.

This kernel is the *multi-version compilation target* of the VELTAIR
reproduction: the (bm, bk, bn) tile shape is the TPU locality knob (bigger
tiles => fewer HBM round-trips => higher arithmetic intensity, but a larger
VMEM working set), and the grid size is the parallelism knob.  The adaptive
compiler (repro.core.multiversion) enumerates tile variants and retains the
Pareto frontier; the runtime selects among them by interference level via
repro.kernels.dispatch.set_tile_overrides.

Grid: (M/bm, N/bn, K/bk) with K innermost; an fp32 VMEM scratch accumulates
partial products across K steps (revisiting output tiles is TPU-idiomatic:
the MXU consumes (bm,bk)x(bk,bn) blocks; accumulation stays on-chip).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jax.Array, mult: tuple[int, int]) -> jax.Array:
    pads = [(0, (-x.shape[i]) % mult[i]) for i in range(2)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def block_matmul_2d(x: jax.Array, w: jax.Array, *, bm: int = 256,
                    bk: int = 512, bn: int = 256,
                    interpret: bool = False) -> jax.Array:
    """x (M,K) @ w (K,N) -> (M,N) with explicit VMEM tiling."""
    m0, k0 = x.shape
    _, n0 = w.shape
    bm, bk, bn = min(bm, _ceil_mult(m0, 8)), min(bk, _ceil_mult(k0, 128)), \
        min(bn, _ceil_mult(n0, 128))
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    m, k = xp.shape
    n = wp.shape[1]
    k_steps = k // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:m0, :n0]


def _ceil_mult(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def vmem_bytes(bm: int, bk: int, bn: int, itemsize: int = 2) -> int:
    """VMEM working set of one grid step (x tile + w tile + fp32 acc)."""
    return bm * bk * itemsize + bk * bn * itemsize + bm * bn * 4
