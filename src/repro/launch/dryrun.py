import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init) — 512 host devices stand in for 2 pods x 256 chips.

For each runnable cell this script:
  1. builds the model + abstract (ShapeDtypeStruct) state/batch/caches —
     no allocation, a 480B model lowers from specs;
  2. jits the train_step / prefill / decode_step with explicit
     in_shardings from the logical-axis rules (dist.sharding);
  3. ``.lower().compile()`` on the production mesh, then records
     memory_analysis(), cost_analysis(), and the collective statistics
     parsed from the optimized HLO (launch.hlo_stats);
  4. appends a JSON record to results/dryrun_<mesh>.jsonl —
     EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline.py read
     these records.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (SHAPES, ARCH_NAMES, get_config, get_shape,
                           shape_applicable)
from repro.dist import sharding as shd
from repro.dist.state_sharding import train_state_specs
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.frontends import input_specs
from repro.models.params import ParamSpec, abstract_params, map_axes
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import TrainConfig, cast_params, \
    make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results")


# Per-arch training knobs: (optimizer, accum_steps, accum_dtype).
# accum keeps per-microbatch activations + fp32 logits inside HBM;
# adafactor (+bf16 accumulation) is what fits the 405B/480B states on a
# single pod (DESIGN.md §5, EXPERIMENTS.md §Dry-run).
TRAIN_KNOBS: dict[str, tuple[str, int, str]] = {
    "llama3-405b": ("adafactor", 16, "bfloat16"),
    "arctic-480b": ("adafactor", 16, "bfloat16"),
    "deepseek-v2-lite-16b": ("adamw", 4, "float32"),
    "gemma-2b": ("adamw", 8, "float32"),
    "minicpm-2b": ("adamw", 8, "float32"),
    "qwen2-vl-2b": ("adamw", 8, "float32"),
    "musicgen-large": ("adamw", 2, "float32"),
    "starcoder2-3b": ("adamw", 4, "float32"),
    "recurrentgemma-2b": ("adamw", 8, "float32"),
    "mamba2-780m": ("adamw", 4, "float32"),
}


def train_config_for(arch: str) -> TrainConfig:
    opt_name, accum, accum_dtype = TRAIN_KNOBS[arch]
    return TrainConfig(
        optimizer=OptimizerConfig(name=opt_name),
        accum_steps=accum, remat="full", accum_dtype=accum_dtype)


def _abstract(specs):
    return abstract_params(specs)


def _shardings(specs, rules_table, rules: shd.RuleSet, mesh):
    pspecs = jax.tree_util.tree_map(
        lambda s: shd.pspec_for(s.shape, s.axes, rules_table, mesh),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return shd.shardings_of(pspecs, mesh)


def build_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
               cfg=None, accum_override: int | None = None,
               shape=None, rules=None):
    """-> (fn, args_abstract, in_shardings, donate, mode).

    ``cfg``/``shape`` override the registered config (roofline lowers
    depth-reduced variants at microbatch size for scan-extrapolation);
    ``accum_override`` pins the microbatch count; ``rules`` overrides the
    sharding rule set (perf hillclimbing sweeps variants)."""
    cfg = cfg or get_config(arch)
    shape = shape or get_shape(shape_name)
    model = build_model(cfg)
    mode = "train" if shape.mode == "train" else "serve"
    rules = rules or shd.make_rules(mode, multi_pod)

    in_specs = input_specs(cfg, shape)
    batch_abs = _abstract(in_specs)
    batch_sh = _shardings(in_specs, rules.acts, rules, mesh)

    if shape.mode == "train":
        tc = train_config_for(arch)
        if accum_override is not None:
            tc = dataclasses.replace(tc, accum_steps=accum_override)
        sspecs = train_state_specs(tc.optimizer, model.param_specs())
        state_abs = _abstract(sspecs)
        state_sh = _shardings(sspecs, rules.params, rules, mesh)
        step = make_train_step(model, tc)

        def fn(state, batch):
            with shd.use_rules(mesh, rules):
                return step(state, batch)
        return fn, (state_abs, batch_abs), (state_sh, batch_sh), (0,), rules

    _ = shape_name
    pspecs_tree = model.param_specs()
    params_abs = _abstract(pspecs_tree)
    params_sh = _shardings(pspecs_tree, rules.params, rules, mesh)
    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
    cache_abs = _abstract(cache_specs)
    cache_sh = _shardings(cache_specs, rules.acts, rules, mesh)

    if shape.mode == "prefill":
        def fn(params, batch, cache):
            with shd.use_rules(mesh, rules):
                return build_model(cfg).prefill(params, batch, cache)
        return fn, (params_abs, batch_abs, cache_abs), \
            (params_sh, batch_sh, cache_sh), (2,), rules

    t_abs = jax.ShapeDtypeStruct((), jnp.int32)
    t_sh = shd.shardings_of(shd.P(), mesh) if False else None

    def fn(params, batch, cache, t):
        with shd.use_rules(mesh, rules):
            return build_model(cfg).decode_step(params, batch, cache, t)
    from jax.sharding import NamedSharding, PartitionSpec
    scalar_sh = NamedSharding(mesh, PartitionSpec())
    return fn, (params_abs, batch_abs, cache_abs, t_abs), \
        (params_sh, batch_sh, cache_sh, scalar_sh), (2,), rules


def _memory_stats(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for key in ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes"):
            if hasattr(ma, key):
                out[key] = int(getattr(ma, key))
    except Exception as e:  # backend may not support it
        out["error"] = str(e)
    return out


def _cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:
        return {"error": str(e)}


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                keep_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "mode": shape.mode}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args_abs, in_sh, donate, rules = build_cell(
            arch, shape_name, mesh, multi_pod)
        with mesh:
            jfn = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
            lowered = jfn.lower(*args_abs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            hlo = compiled.as_text()
        coll = hlo_stats.parse_collectives(hlo)
        n_dev = mesh.size
        rec.update(
            status="ok",
            n_devices=n_dev,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=_memory_stats(compiled),
            cost=_cost_stats(compiled),
            collectives={
                "counts": coll.counts,
                "payload_bytes": coll.payload_bytes,
                "link_bytes_per_dev": coll.link_bytes,
            },
        )
        if shape.mode == "train":
            rec["train_knobs"] = dict(zip(
                ("optimizer", "accum_steps", "accum_dtype"),
                TRAIN_KNOBS[arch]))
        if keep_hlo:
            rec["hlo_lines"] = len(hlo.splitlines())
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list(ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    os.makedirs(os.path.abspath(RESULTS_DIR), exist_ok=True)
    out_path = args.out or os.path.abspath(
        os.path.join(RESULTS_DIR, f"dryrun_{mesh_tag}.jsonl"))

    for arch in archs:
        for shape_name in shapes:
            rec = dryrun_cell(arch, shape_name, multi_pod=args.multi_pod)
            line = json.dumps(rec)
            with open(out_path, "a") as f:
                f.write(line + "\n")
            mem = rec.get("memory", {})
            print(f"[dryrun] {arch} x {shape_name} @ {mesh_tag}: "
                  f"{rec['status']}"
                  + (f" (compile {rec.get('compile_s')}s, "
                     f"args {mem.get('argument_size_in_bytes', 0)/2**30:.2f}"
                     f" GiB/dev, temp "
                     f"{mem.get('temp_size_in_bytes', 0)/2**30:.2f} GiB/dev)"
                     if rec["status"] == "ok" else
                     f" {rec.get('reason', rec.get('error', ''))}"),
                  flush=True)


if __name__ == "__main__":
    main()
