"""Parse collective-communication statistics out of compiled HLO text.

cost_analysis() gives FLOPs and memory bytes but not collective traffic, so
the roofline's third term comes from scanning the post-SPMD optimized HLO
for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, taking each op's payload from its result shape and
its group size from replica_groups, and converting to per-device link bytes
with the standard ring-collective factors:

    all-gather          (n-1)/n * result_bytes
    all-reduce        2*(n-1)/n * result_bytes
    reduce-scatter      (n-1)   * result_bytes     (operand = n * result)
    all-to-all          (n-1)/n * result_bytes
    collective-permute           result_bytes
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")

# `%x = bf16[1,2]{...} all-reduce(` or `%x = (bf16[..], ..) all-gather-start(`
_INST_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return default


def _link_bytes(op: str, result_bytes: int, n: int) -> float:
    if op == "collective-permute":
        return float(result_bytes)    # point-to-point, no group concept
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return (n - 1) / n * result_bytes
    if op == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if op == "reduce-scatter":
        return (n - 1) * result_bytes
    if op == "all-to-all":
        return (n - 1) / n * result_bytes
    return float(result_bytes)          # collective-permute


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    payload_bytes: dict
    link_bytes: float                   # per-device, summed over ops

    def total_payload(self) -> float:
        return sum(self.payload_bytes.values())


def parse_collectives(hlo_text: str, default_group: int = 1,
                      ) -> CollectiveStats:
    counts = {op: 0 for op in _OPS}
    payload = {op: 0.0 for op in _OPS}
    link = 0.0
    for line in hlo_text.splitlines():
        m = _INST_RE.search(line)
        if not m:
            continue
        type_str, op, start = m.group(1), m.group(2), m.group(3)
        rb = _shape_bytes(type_str)
        if start:
            # -start result tuples carry (operand, result) aliases; halve
            rb = rb // 2
        n = _group_size(line, default_group)
        counts[op] += 1
        payload[op] += rb
        link += _link_bytes(op, rb, n)
    return CollectiveStats(counts=counts, payload_bytes=payload,
                           link_bytes=link)


def count_op(hlo_text: str, opcode: str) -> int:
    return len(re.findall(rf"\s{re.escape(opcode)}\(", hlo_text))
