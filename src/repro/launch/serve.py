"""Serving entry point: batched requests through the ServingEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 8 --prompt-len 16 --max-new 8
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_slots=args.slots,
                           max_len=args.prompt_len + args.max_new + 2)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = engine.run_to_completion(reqs)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"[serve] {cfg.name}: {len(done)}/{args.requests} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.output[:10]}")


if __name__ == "__main__":
    main()
