"""Distributed training entry point.

    PYTHONPATH=src python -m repro.launch.train \
        --arch minicpm-2b --reduced --steps 100 --ckpt-dir /tmp/ckpt

Full configs train with the production-mesh shardings (requires real
hardware or the dry-run's forced device count); ``--reduced`` runs the
same code path on the local device(s) — the e2e example trains a ~small
model for a few hundred steps on CPU.
"""
import argparse

import jax

from repro.configs import get_config, get_reduced_config
from repro.data import DataConfig
from repro.models import build_model
from repro.training import OptimizerConfig, TrainConfig
from repro.training.train_loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--schedule", default="wsd")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    model = build_model(cfg)
    tc = TrainConfig(
        optimizer=OptimizerConfig(name=args.optimizer, lr=args.lr,
                                  schedule=args.schedule,
                                  warmup_steps=max(args.steps // 10, 1),
                                  total_steps=args.steps),
        accum_steps=args.accum)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.global_batch)
    lc = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                    ckpt_every=args.ckpt_every)
    out = train_loop(model, tc, dc, lc)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"[train] {cfg.name}: loss {first:.3f} -> {last:.3f} "
          f"on {len(jax.devices())} device(s)")


if __name__ == "__main__":
    main()
