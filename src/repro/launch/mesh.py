"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips single pod; 2x16x16 = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))
