"""A heuristic, whole-corpus call graph over the scanned files.

The graph is deliberately approximate — this is a linter, not a type
checker.  Names are resolved in four passes of decreasing confidence:

1. ``self.method(...)`` → a method on the same class.
2. A local/imported name (``from repro.x import y``; ``import m as z``)
   → the function/class it binds in the corpus.
3. ``self.attr.meth(...)`` → ``Class.meth`` when ``attr``'s class is
   known from a ``self.attr = ClassName(...)`` assignment or a class
   annotation anywhere in the corpus.
4. A unique bare method name across the whole corpus (skipped when the
   name is defined in more than one class — ambiguity beats noise).

Calls inside nested ``def``s (jit closures such as the quantum body in
``VersionCache.quantum``) are attributed to the outermost enclosing
function, so trace-time model code is pulled into hot-path slices.
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis import astutil
from repro.analysis.astutil import SourceFile


@dataclasses.dataclass
class FunctionInfo:
    """One top-level function or method in the corpus."""
    qual: str                      # "module:Class.method" or "module:func"
    sf: SourceFile
    node: ast.AST                  # FunctionDef / AsyncFunctionDef
    cls: str | None                # owning class name, if a method


class CallGraph:
    def __init__(self, files: list[SourceFile]):
        self.files = [f for f in files if f.tree is not None]
        self.functions: dict[str, FunctionInfo] = {}
        # method name -> list of quals that define it (for passes 1 & 4)
        self.by_method: dict[str, list[str]] = {}
        # bare function name -> list of quals (for pass 2 resolution)
        self.by_name: dict[str, list[str]] = {}
        # class name -> {method name -> qual}
        self.classes: dict[str, dict[str, str]] = {}
        # attr name -> class name, learned from `self.attr = Class(...)`
        # and `attr: Class` annotations, corpus-wide
        self.attr_types: dict[str, str] = {}
        self.edges: dict[str, set[str]] = {}
        self._import_cache: dict[str, dict[str, str]] = {}
        self._index()
        self._infer_attr_types()
        self._build_edges()

    # -- indexing -----------------------------------------------------
    def _index(self) -> None:
        for sf in self.files:
            for node in ast.walk(sf.tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if astutil.enclosing_function(node) is not None:
                    continue  # nested def: owned by its outer function
                qualname = astutil.func_qualname(node)
                cls = None
                owner = astutil.enclosing(node, ast.ClassDef)
                if isinstance(owner, ast.ClassDef):
                    cls = owner.name
                qual = f"{sf.module}:{qualname}"
                info = FunctionInfo(qual=qual, sf=sf, node=node, cls=cls)
                self.functions[qual] = info
                self.by_name.setdefault(node.name, []).append(qual)
                if cls is not None:
                    self.by_method.setdefault(node.name, []).append(qual)
                    self.classes.setdefault(cls, {})[node.name] = qual

    def _infer_attr_types(self) -> None:
        class_names = set(self.classes)
        for sf in self.files:
            for node in ast.walk(sf.tree):
                # self.attr = ClassName(...)
                if isinstance(node, ast.Assign) and len(node.targets) == 1:
                    tgt = node.targets[0]
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"
                            and isinstance(node.value, ast.Call)):
                        cname = astutil.dotted_name(node.value.func)
                        if cname:
                            tail = cname.split(".")[-1]
                            if tail in class_names:
                                self.attr_types[tgt.attr] = tail
                # attr: ClassName  (class-level or self.attr annotation)
                if isinstance(node, ast.AnnAssign):
                    tgt = node.target
                    attr = None
                    if isinstance(tgt, ast.Name):
                        attr = tgt.id
                    elif (isinstance(tgt, ast.Attribute)
                          and isinstance(tgt.value, ast.Name)
                          and tgt.value.id == "self"):
                        attr = tgt.attr
                    if attr is not None:
                        ann = astutil.dotted_name(node.annotation)
                        if ann:
                            tail = ann.split(".")[-1]
                            if tail in class_names:
                                self.attr_types[attr] = tail
        # constructor-style "engine = ServingEngine(...)" locals too:
        for sf in self.files:
            for node in ast.walk(sf.tree):
                if (isinstance(node, ast.Assign) and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and isinstance(node.value, ast.Call)):
                    cname = astutil.dotted_name(node.value.func)
                    if cname and cname.split(".")[-1] in class_names:
                        self.attr_types.setdefault(
                            node.targets[0].id, cname.split(".")[-1])

    # -- name resolution ----------------------------------------------
    def _imports_of(self, sf: SourceFile) -> dict[str, str]:
        """local alias -> dotted module or module.symbol target."""
        out: dict[str, str] = {}
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Import):
                for al in node.names:
                    out[al.asname or al.name.split(".")[0]] = al.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for al in node.names:
                    out[al.asname or al.name] = f"{node.module}.{al.name}"
        return out

    def _resolve_call(self, sf: SourceFile, imports: dict[str, str],
                      caller: FunctionInfo, call: ast.Call) -> str | None:
        fn = call.func
        # self.method(...)
        if (isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name)
                and fn.value.id == "self" and caller.cls is not None):
            hit = self.classes.get(caller.cls, {}).get(fn.attr)
            if hit:
                return hit
            # inherited / mixin method: fall through to unique-name pass
        name = astutil.dotted_name(fn)
        if name is None and isinstance(fn, ast.Attribute):
            name = fn.attr  # x().meth / x[0].meth → bare method name
        if name is None:
            return None
        parts = name.split(".")
        # bare local or imported function name
        if len(parts) == 1:
            target = imports.get(parts[0], parts[0])
            tail = target.split(".")[-1]
            mod = ".".join(target.split(".")[:-1])
            for qual in self.by_name.get(tail, []):
                info = self.functions[qual]
                if info.cls is None and (not mod
                                         or info.sf.module == mod
                                         or qual.startswith(mod + ":")):
                    return qual
            # class constructor → __init__
            if tail in self.classes:
                return self.classes[tail].get("__init__")
            cand = self.by_name.get(parts[0], [])
            if len(cand) == 1:
                return cand[0]
            return None
        # obj.meth(...) or module.func(...) or self.attr.meth(...)
        head, meth = parts[0], parts[-1]
        if head == "self" and len(parts) >= 3:
            head = parts[1]  # self.attr.meth → attr's class
        cls = self.attr_types.get(head)
        if cls:
            hit = self.classes.get(cls, {}).get(meth)
            if hit:
                return hit
        # module alias: mod.func
        target = imports.get(head)
        if target:
            for qual in self.by_name.get(meth, []):
                info = self.functions[qual]
                if info.sf.module == target or info.sf.module.endswith(
                        "." + target.split(".")[-1]):
                    if info.cls is None:
                        return qual
            if meth in self.classes:  # mod.ClassName(...)
                return self.classes[meth].get("__init__")
        # unique method name across corpus (last resort; skip ambiguous)
        cand = self.by_method.get(meth, [])
        if len(cand) == 1:
            return cand[0]
        return None

    def _build_edges(self) -> None:
        for qual, info in self.functions.items():
            imports = self._imports_of(info.sf)
            callees: set[str] = set()
            for node in ast.walk(info.node):
                if isinstance(node, ast.Call):
                    tgt = self._resolve_call(info.sf, imports, info, node)
                    if tgt and tgt != qual:
                        callees.add(tgt)
            self.edges[qual] = callees

    # -- queries ------------------------------------------------------
    def resolve(self, caller_qual: str, call: ast.Call) -> str | None:
        """Public resolution entry point for rules: resolve ``call``
        made inside ``caller_qual`` to a corpus function qual."""
        info = self.functions.get(caller_qual)
        if info is None:
            return None
        imports = self._import_cache.get(info.sf.module)
        if imports is None:
            imports = self._imports_of(info.sf)
            self._import_cache[info.sf.module] = imports
        return self._resolve_call(info.sf, imports, info, call)

    def find(self, suffix: str) -> list[str]:
        """All quals whose ``module:Qual.name`` ends with ``suffix``
        (match on Class.method or function-name boundaries)."""
        out = []
        for qual in self.functions:
            tail = qual.split(":", 1)[1]
            if tail == suffix or tail.endswith("." + suffix):
                out.append(qual)
        return out

    def reachable(self, roots: list[str]) -> set[str]:
        seen: set[str] = set()
        stack = [r for r in roots if r in self.functions]
        while stack:
            cur = stack.pop()
            if cur in seen:
                continue
            seen.add(cur)
            stack.extend(self.edges.get(cur, ()))
        return seen

    def callers_of(self, qual: str) -> set[str]:
        return {src for src, dsts in self.edges.items() if qual in dsts}

    def connected(self, roots: list[str]) -> set[str]:
        """Reachable-from-roots plus transitive callers of roots (used
        for the paged-leaf rule, where helpers both call and are called
        by the ``cache_specs`` anchor)."""
        seen = self.reachable(roots)
        frontier = [r for r in roots if r in self.functions]
        back: set[str] = set(frontier)
        while frontier:
            cur = frontier.pop()
            for caller in self.callers_of(cur):
                if caller not in back:
                    back.add(caller)
                    frontier.append(caller)
        return seen | back
