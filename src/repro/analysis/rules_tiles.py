"""Rule 5 — ``tile-table-atomicity``.

The dispatch override table is shared mutable state read by every
kernel launch.  ``install_tile_overrides``/``install_ladder`` replace
it wholesale (old ops cleared, new ops installed in one call), so a
level switch can never leave a half-old/half-new table for a
concurrently tracing tenant.  Per-op ``set_tile_overrides`` and direct
pokes at ``_TILE_OVERRIDES``/``_CONTEXT_STACK``/``_LADDER`` do not have
that property — N per-op calls = N-1 observable torn states — which is
exactly the "corrupted shared config" interference VELTAIR's adaptive
compilation must exclude.  Everything outside ``kernels/dispatch.py``
(the owning module) must go through the atomic installers.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.base import AnalysisContext, Rule, Violation, register

_GLOBALS = {"_TILE_OVERRIDES", "_CONTEXT_STACK", "_LADDER"}
_MUTATORS = {"clear", "update", "append", "pop", "setdefault", "extend",
             "insert", "remove"}
_OWNER_FILE = "dispatch.py"


def _names_global(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name) and node.id in _GLOBALS:
        return node.id
    if isinstance(node, ast.Attribute) and node.attr in _GLOBALS:
        return node.attr
    return None


class TileAtomicityRule(Rule):
    rule_id = "tile-table-atomicity"
    description = ("dispatch override state changes only via "
                   "install_tile_overrides/install_ladder")

    def check(self, ctx: AnalysisContext) -> list[Violation]:
        out: list[Violation] = []
        for sf in ctx.parsed():
            if sf.path.name == _OWNER_FILE:
                continue
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Call):
                    name = astutil.dotted_name(node.func) or ""
                    if name.split(".")[-1] == "set_tile_overrides":
                        out.append(self.violation(
                            sf, node, "per-op set_tile_overrides() is "
                            "not atomic across ops — a concurrent trace "
                            "can observe a torn tile table; use "
                            "install_tile_overrides({...}) (or "
                            "tile_context for scoped overrides)"))
                        continue
                    # _TILE_OVERRIDES.update(...) style method mutation
                    if isinstance(node.func, ast.Attribute) and \
                            node.func.attr in _MUTATORS:
                        g = _names_global(node.func.value)
                        if g:
                            out.append(self.violation(
                                sf, node, f"direct {g}.{node.func.attr}() "
                                f"mutation outside kernels/dispatch.py — "
                                f"use install_tile_overrides/"
                                f"install_ladder"))
                        continue
                # stores: X = ..., X[k] = ..., del X[k]
                targets: list[ast.AST] = []
                if isinstance(node, ast.Assign):
                    targets = list(node.targets)
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    targets = [node.target]
                elif isinstance(node, ast.Delete):
                    targets = list(node.targets)
                for tgt in targets:
                    base = tgt.value if isinstance(
                        tgt, ast.Subscript) else tgt
                    g = _names_global(base)
                    if g:
                        out.append(self.violation(
                            sf, tgt, f"direct write to {g} outside "
                            f"kernels/dispatch.py — use "
                            f"install_tile_overrides/install_ladder"))
        return out


register(TileAtomicityRule())
