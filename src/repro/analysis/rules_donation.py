"""Rule 2 — ``use-after-donation``.

``donate_argnums`` hands a buffer to XLA: after the call, the Python
binding still points at the now-invalid array, and any later read is
silently garbage (or an error under ``jax_debug_donations``).  The
serving path leans on donation everywhere — the decode/quantum
executables donate the cache (PR 4), the row writers donate position 0
— so a use-after-donation is exactly the "corrupted shared buffer"
failure mode VELTAIR's QoS argument assumes away.

The rule tracks three ways a donated callable reaches a call site:

* directly: ``fn = jax.jit(f, donate_argnums=(2,))`` (optionally via
  ``.lower(...).compile()``);
* through a factory: a corpus function that *returns* a donated
  callable (``_make_row_writer``, ``VersionCache.quantum``) marks its
  call results as donated;
* through an attribute: ``self._row_writer = self._make_row_writer()``
  or ``VersionEntry(decode=jax.jit(..., donate_argnums=(2,)))`` mark
  the attribute name, and ``entry.decode`` / alias reads inherit it.

Within each function the scan is linear in source order: passing a
name (or dotted path such as ``self.cache``) at a donated position
consumes it; a read before the next rebind is a violation.  Rebinding
in the *same* statement (``self.cache = writer(self.cache, ...)`` — the
repo idiom) is clean by construction.  The scan is flow-insensitive
across branches, which is the usual linter trade-off.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.base import AnalysisContext, Rule, Violation, register

_JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pjit"}


def _donate_positions(call: ast.Call) -> tuple[int, ...] | None:
    """Donated argnums of a jit call, or None if it doesn't donate (or
    donates non-literally, which we conservatively skip)."""
    for kw in call.keywords:
        if kw.arg in {"donate_argnums", "donate"}:
            v = astutil.int_const(kw.value)
            if v is not None:
                return (v,)
            tup = astutil.const_str_tuple(kw.value)
            if tup is not None and all(isinstance(x, int) for x in tup):
                return tuple(tup)
            return ()   # donates, positions unknown → track as donated
    return None


def _unwrap_aot(node: ast.AST) -> ast.AST:
    """Peel ``.lower(...).compile()`` / ``.compile()`` wrappers so the
    inner ``jax.jit(...)`` call is visible."""
    while (isinstance(node, ast.Call)
           and isinstance(node.func, ast.Attribute)
           and node.func.attr in {"lower", "compile"}):
        node = node.func.value
    return node


def _donated_jit_expr(node: ast.AST) -> tuple[int, ...] | None:
    inner = _unwrap_aot(node)
    if isinstance(inner, ast.Call):
        name = astutil.dotted_name(inner.func)
        if name in _JIT_NAMES:
            return _donate_positions(inner)
    return None


def _iter_stmts(fn: ast.AST):
    """Statements of ``fn`` in source order, excluding nested ``def``
    bodies (donation consumes in the *caller's* frame; the traced
    closure legitimately reads its own parameters)."""
    def walk(body):
        for stmt in body:
            yield stmt
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for name in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, name, None)
                if isinstance(sub, list):
                    yield from walk(sub)
            for handler in getattr(stmt, "handlers", []) or []:
                yield from walk(handler.body)
    yield from walk(fn.body)  # type: ignore[union-attr]


def _stmt_scan_roots(stmt: ast.stmt) -> list[ast.AST]:
    """The sub-expressions belonging to *this* statement alone: compound
    statements contribute only their header (iter/test/context), because
    their body statements are visited separately by ``_iter_stmts``."""
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.iter]
    if isinstance(stmt, (ast.While, ast.If)):
        return [stmt.test]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [item.context_expr for item in stmt.items]
    if isinstance(stmt, (ast.Try, ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.ClassDef)):
        return []
    return [stmt]


def _calls_in(stmt: ast.stmt):
    """Call nodes belonging to a statement (header-only for compound
    statements), excluding nested function bodies."""
    stack = list(_stmt_scan_roots(stmt))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not stmt:
            continue
        if isinstance(node, ast.Call):
            yield node
        stack.extend(ast.iter_child_nodes(node))


class DonationRule(Rule):
    rule_id = "use-after-donation"
    description = ("no read of a binding after it was passed at a "
                   "donate_argnums position")

    def check(self, ctx: AnalysisContext) -> list[Violation]:
        factories = self._find_factories(ctx)
        attr_donated = self._find_donated_attrs(ctx, factories)
        out: list[Violation] = []
        for qual, info in sorted(ctx.graph.functions.items()):
            out.extend(self._scan_function(
                ctx, qual, info, factories, attr_donated))
        return out

    # -- corpus passes ------------------------------------------------
    def _find_factories(self, ctx: AnalysisContext) -> dict[str, tuple]:
        """Functions that return a donated callable → donated positions.
        Two fixed-point iterations cover factory-of-factory chains."""
        factories: dict[str, tuple] = {}
        for _ in range(2):
            for qual, info in ctx.graph.functions.items():
                local: dict[str, tuple] = {}
                for stmt in _iter_stmts(info.node):
                    if isinstance(stmt, ast.Assign) and len(
                            stmt.targets) == 1 and isinstance(
                            stmt.targets[0], ast.Name):
                        pos = self._donated_value(
                            ctx, qual, stmt.value, local, factories, {})
                        if pos is not None:
                            local[stmt.targets[0].id] = pos
                    if isinstance(stmt, ast.Return) and stmt.value:
                        pos = self._donated_value(
                            ctx, qual, stmt.value, local, factories, {})
                        if pos is not None:
                            factories[qual] = pos
        return factories

    def _find_donated_attrs(self, ctx: AnalysisContext,
                            factories: dict[str, tuple]) -> dict[str, tuple]:
        """Attribute/field names bound to donated callables anywhere:
        ``self.x = <donated>`` and ``Cls(field=<donated>)``."""
        attrs: dict[str, tuple] = {}
        for qual, info in ctx.graph.functions.items():
            for stmt in _iter_stmts(info.node):
                if isinstance(stmt, ast.Assign) and len(
                        stmt.targets) == 1 and isinstance(
                        stmt.targets[0], ast.Attribute):
                    pos = self._donated_value(
                        ctx, qual, stmt.value, {}, factories, {})
                    if pos is not None:
                        attrs[stmt.targets[0].attr] = pos
                for call in _calls_in(stmt):
                    for kw in call.keywords:
                        if kw.arg is None:
                            continue
                        pos = _donated_jit_expr(kw.value)
                        if pos is not None:
                            attrs[kw.arg] = pos
        return attrs

    def _donated_value(self, ctx, qual, value, local, factories,
                       attr_donated) -> tuple | None:
        """Donation positions of an expression, or None."""
        pos = _donated_jit_expr(value)
        if pos is not None:
            return pos
        if isinstance(value, ast.Name) and value.id in local:
            return local[value.id]
        if isinstance(value, ast.Attribute) and \
                value.attr in attr_donated:
            return attr_donated[value.attr]
        if isinstance(value, ast.Call):
            tgt = ctx.graph.resolve(qual, value)
            if tgt and tgt in factories:
                return factories[tgt]
        return None

    # -- per-function scan --------------------------------------------
    def _scan_function(self, ctx, qual, info, factories,
                       attr_donated) -> list[Violation]:
        out: list[Violation] = []
        local: dict[str, tuple] = {}        # name -> donated positions
        consumed: dict[str, int] = {}       # binding path -> call line
        for stmt in _iter_stmts(info.node):
            # 1. reads of already-consumed bindings (header-only for
            #    compound statements — bodies are visited on their own)
            stack = list(_stmt_scan_roots(stmt))
            while stack:
                node = stack.pop()
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)) and node is not stmt:
                    continue
                stack.extend(ast.iter_child_nodes(node))
                path = None
                if isinstance(node, ast.Name) and isinstance(
                        node.ctx, ast.Load):
                    path = node.id
                elif isinstance(node, ast.Attribute) and isinstance(
                        node.ctx, ast.Load):
                    path = astutil.dotted_name(node)
                if path and path in consumed:
                    out.append(self.violation(
                        info.sf, node,
                        f"`{path}` read after being donated at line "
                        f"{consumed[path]} (buffer is invalid after "
                        f"donation)"))
                    consumed.pop(path, None)  # one report per donation
            # 2. consumption at donated positions
            newly: dict[str, int] = {}
            for call in _calls_in(stmt):
                pos = self._call_donates(ctx, qual, call, local,
                                         attr_donated, factories)
                if not pos:
                    continue
                for p in pos:
                    if p < len(call.args):
                        arg = call.args[p]
                        path = (arg.id if isinstance(arg, ast.Name)
                                else astutil.dotted_name(arg)
                                if isinstance(arg, ast.Attribute)
                                else None)
                        if path:
                            newly[path] = call.lineno
            # 3. rebinds clear consumption (same-statement rebind of the
            #    donated arg — the repo idiom — never flags)
            for tgt in self._stmt_targets(stmt):
                newly.pop(tgt, None)
                consumed.pop(tgt, None)
                local.pop(tgt, None)
            consumed.update(newly)
            # 4. track donated-callable bindings (after the rebind pass,
            #    so this statement's own target is not wiped)
            if isinstance(stmt, ast.Assign) and len(
                    stmt.targets) == 1 and isinstance(
                    stmt.targets[0], ast.Name):
                pos = self._donated_value(
                    ctx, qual, stmt.value, local, factories, attr_donated)
                if pos is not None:
                    local[stmt.targets[0].id] = pos
        return out

    def _call_donates(self, ctx, qual, call, local, attr_donated,
                      factories) -> tuple | None:
        """Donated positions if ``call`` invokes a donated callable."""
        fn = call.func
        if isinstance(fn, ast.Name) and fn.id in local:
            return local[fn.id]
        if isinstance(fn, ast.Attribute) and fn.attr in attr_donated:
            return attr_donated[fn.attr]
        inner = _donated_jit_expr(fn)   # jax.jit(f, donate...)(args)
        if inner is not None:
            return inner
        return None

    def _stmt_targets(self, stmt: ast.stmt) -> list[str]:
        out: list[str] = []
        targets: list = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            targets = [stmt.target]
        elif isinstance(stmt, ast.For):
            targets = [stmt.target]
        for tgt in targets:
            stack = [tgt]
            while stack:
                t = stack.pop()
                if isinstance(t, ast.Name):
                    out.append(t.id)
                elif isinstance(t, ast.Attribute):
                    d = astutil.dotted_name(t)
                    if d:
                        out.append(d)
                elif isinstance(t, (ast.Tuple, ast.List, ast.Starred)):
                    stack.extend(getattr(t, "elts", None)
                                 or [t.value])
        return out


register(DonationRule())
