"""Rule 3 — ``retrace-hazard``.

Zero post-warmup retraces is the load-bearing invariant of PRs 2/5/8/9:
one unplanned XLA compile costs ~180ms — more than an entire QoS window.
Every compiled-shape knob in the repo is therefore quantized to
power-of-two buckets through sanctioned helpers.  This rule flags the
three ways fresh code reintroduces retraces:

* a **K argument** to ``VersionCache.quantum``/``spec_quantum`` that is
  not visibly bucketed — sanctioned forms are int literals, values
  drawn from a ``*bucket*``-named collection (loop var, ``next(...)``
  over it, subscript of it, ``min``/``max`` of sanctioned values), or a
  call to ``_next_pow2``/``pages_for``;
* a **mutable literal** (list/dict/set display) passed at a
  ``static_argnums`` position of an immediately-invoked ``jax.jit`` —
  unhashable statics raise at best and silently retrace at worst;
* ``len(...)`` flowing into the **shape argument** of a ``jnp``
  array constructor without a bucketing wrapper — per-request lengths
  mean one compile per distinct length.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.base import AnalysisContext, Rule, Violation, register

SANCTIONED_HELPERS = {"_next_pow2", "next_pow2", "pages_for"}
_SHAPE_CTORS = {"zeros", "ones", "full", "empty", "arange", "broadcast_to"}


def _bucketish(expr: ast.AST) -> bool:
    """Does the expression mention a ``*bucket*``-named binding?"""
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and "bucket" in node.id.lower():
            return True
        if isinstance(node, ast.Attribute) and "bucket" in node.attr.lower():
            return True
    return False


def _helper_call(expr: ast.AST) -> bool:
    if isinstance(expr, ast.Call):
        name = astutil.dotted_name(expr.func) or ""
        if name.split(".")[-1] in SANCTIONED_HELPERS:
            return True
    return False


class _Sanction:
    """Per-function set of names known to hold bucketed values."""

    def __init__(self, fn: ast.AST):
        self.names: set[str] = set()
        for _ in range(2):      # two passes: alias-of-alias stabilizes
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and len(
                        node.targets) == 1 and isinstance(
                        node.targets[0], ast.Name):
                    if self.expr_ok(node.value):
                        self.names.add(node.targets[0].id)
                elif isinstance(node, ast.For) and isinstance(
                        node.target, ast.Name):
                    if _bucketish(node.iter):
                        self.names.add(node.target.id)

    def expr_ok(self, expr: ast.AST) -> bool:
        if astutil.int_const(expr) is not None:
            return True
        if _helper_call(expr) or _bucketish(expr):
            return True
        if isinstance(expr, ast.Name):
            return expr.id in self.names
        if isinstance(expr, ast.Call):
            name = astutil.dotted_name(expr.func) or ""
            if name in {"min", "max"} and expr.args:
                return all(self.expr_ok(a) or _bucketish(a)
                           for a in expr.args)
            if name == "next" and expr.args and _bucketish(expr.args[0]):
                return True
        if isinstance(expr, ast.Subscript):
            return _bucketish(expr.value)
        return False


class RetraceRule(Rule):
    rule_id = "retrace-hazard"
    description = ("compiled-shape knobs must flow through pow2-bucket "
                   "helpers; statics must be hashable")

    def check(self, ctx: AnalysisContext) -> list[Violation]:
        out: list[Violation] = []
        for qual, info in sorted(ctx.graph.functions.items()):
            sanction = _Sanction(info.node)
            for node in ast.walk(info.node):
                if not isinstance(node, ast.Call):
                    continue
                out.extend(self._check_k_arg(info.sf, node, sanction))
                out.extend(self._check_static_literal(info.sf, node))
                out.extend(self._check_shape_len(info.sf, node))
        return out

    def _check_k_arg(self, sf, call: ast.Call,
                     sanction: _Sanction) -> list[Violation]:
        if not (isinstance(call.func, ast.Attribute)
                and call.func.attr in {"quantum", "spec_quantum"}):
            return []
        if len(call.args) < 2 or isinstance(call.args[1], ast.Starred):
            return []
        k = call.args[1]
        if sanction.expr_ok(k):
            return []
        src = ast.unparse(k) if hasattr(ast, "unparse") else "<expr>"
        return [self.violation(
            sf, k, f"K argument `{src}` to .{call.func.attr}() is not "
            f"visibly bucketed (use a *_buckets collection or "
            f"_next_pow2/pages_for) — every distinct value is a fresh "
            f"trace + AOT compile")]

    def _check_static_literal(self, sf, call: ast.Call) -> list[Violation]:
        # jax.jit(f, static_argnums=(i,))(... mutable literal at i ...)
        inner = call.func
        if not isinstance(inner, ast.Call):
            return []
        name = astutil.dotted_name(inner.func) or ""
        if name not in {"jax.jit", "jit", "functools.partial"}:
            return []
        positions: list[int] = []
        for kw in inner.keywords:
            if kw.arg == "static_argnums":
                v = astutil.int_const(kw.value)
                if v is not None:
                    positions = [v]
                else:
                    tup = astutil.const_str_tuple(kw.value) or ()
                    positions = [x for x in tup if isinstance(x, int)]
        out = []
        for p in positions:
            if p < len(call.args) and isinstance(
                    call.args[p], (ast.List, ast.Dict, ast.Set)):
                out.append(self.violation(
                    sf, call.args[p],
                    f"mutable literal at static_argnums position {p}: "
                    f"unhashable statics raise TypeError (or retrace "
                    f"per call via id())"))
        return out

    def _check_shape_len(self, sf, call: ast.Call) -> list[Violation]:
        name = astutil.dotted_name(call.func) or ""
        parts = name.split(".")
        if len(parts) < 2 or parts[-1] not in _SHAPE_CTORS or \
                parts[0] not in {"jnp", "jax"}:
            return []
        if not call.args:
            return []
        out = []
        # walk the shape arg, skipping sanctioned-helper subtrees
        stack = [call.args[0]]
        while stack:
            node = stack.pop()
            if _helper_call(node):
                continue        # _next_pow2(len(x)) is the sanctioned form
            if isinstance(node, ast.Call):
                n = astutil.dotted_name(node.func) or ""
                if n == "len":
                    out.append(self.violation(
                        sf, node, f"len() flows into a {name}() shape — "
                        f"per-request lengths retrace per distinct value; "
                        f"bucket with _next_pow2/pages_for"))
                    continue
            stack.extend(ast.iter_child_nodes(node))
        return out

    # fixture hook: violation() inherited


register(RetraceRule())
