"""AST plumbing for the static analyzer: file loading, parent links,
qualified names, dotted-name helpers, and suppression-comment scanning.

Everything here is stdlib-``ast`` based (no new dependencies) and purely
syntactic: the analyzer never imports the code it checks, so it can run
over a broken tree (that is rule 0's whole point) and over fixture
snippets that are not importable packages.

Suppressions: a violation is suppressed by a comment on the violating
line or the line directly above it::

    x = int(logits.max())   # veltair: ignore[host-sync-in-hot-path] why

The bracket list may name several rules (comma-separated) or ``*`` for
all rules; text after the bracket is the (required by convention)
one-line justification.  The ``syntax`` rule cannot be suppressed — a
file that does not parse cannot be trusted to carry comments.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

SUPPRESS_RE = re.compile(
    r"#\s*veltair:\s*ignore\[([A-Za-z0-9_\-*,\s]+)\]")


@dataclasses.dataclass
class SourceFile:
    """One parsed source file plus the side tables rules consume."""
    path: pathlib.Path
    module: str                          # dotted module name ("repro.x.y")
    text: str
    tree: ast.Module | None              # None when the file does not parse
    error: SyntaxError | None = None
    # line -> set of rule ids suppressed there ("*" = every rule)
    suppressions: dict[int, set[str]] = dataclasses.field(
        default_factory=dict)

    def suppressed(self, line: int, rule_id: str) -> bool:
        """Is ``rule_id`` suppressed at ``line`` (same line or the line
        directly above)?  ``syntax`` is never suppressible."""
        if rule_id == "syntax":
            return False
        for ln in (line, line - 1):
            ids = self.suppressions.get(ln)
            if ids and ("*" in ids or rule_id in ids):
                return True
        return False


def scan_suppressions(text: str) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = SUPPRESS_RE.search(line)
        if m:
            ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
            if ids:
                out[i] = ids
    return out


def load_file(path: pathlib.Path, module: str) -> SourceFile:
    text = path.read_text(encoding="utf-8", errors="replace")
    try:
        tree = ast.parse(text, filename=str(path))
        err = None
    except SyntaxError as e:
        tree, err = None, e
    sf = SourceFile(path=path, module=module, text=text, tree=tree,
                    error=err, suppressions=scan_suppressions(text))
    if tree is not None:
        attach_parents(tree)
    return sf


def attach_parents(tree: ast.AST) -> None:
    """Store a ``_vl_parent`` backlink on every node (rules walk up to
    find the enclosing statement / function / class)."""
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._vl_parent = node  # type: ignore[attr-defined]


def parent(node: ast.AST) -> ast.AST | None:
    return getattr(node, "_vl_parent", None)


def enclosing(node: ast.AST, *types) -> ast.AST | None:
    """Nearest ancestor of one of ``types`` (the node itself excluded)."""
    cur = parent(node)
    while cur is not None and not isinstance(cur, types):
        cur = parent(cur)
    return cur


def enclosing_statement(node: ast.AST) -> ast.stmt | None:
    cur: ast.AST | None = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = parent(cur)
    return cur  # type: ignore[return-value]


def enclosing_function(node: ast.AST) -> ast.AST | None:
    """Nearest enclosing *top-level* function or method: nested ``def``s
    (jit closures, local helpers) are attributed to the outermost
    function that owns them, which is what the call graph indexes."""
    fn = None
    cur = parent(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn = cur
        cur = parent(cur)
    return fn


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None (calls,
    subscripts and literals break the chain)."""
    parts: list[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def func_qualname(fn: ast.AST) -> str:
    """``Class.method`` or ``func`` for a top-level def (nested defs get
    their outermost owner's name — see :func:`enclosing_function`)."""
    names = [fn.name]  # type: ignore[union-attr]
    cur = parent(fn)
    while cur is not None:
        if isinstance(cur, (ast.ClassDef, ast.FunctionDef,
                            ast.AsyncFunctionDef)):
            names.append(cur.name)
        cur = parent(cur)
    return ".".join(reversed(names))


def const_str_tuple(node: ast.AST) -> tuple | None:
    """A tuple/list display of constants as a python tuple, else None."""
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant):
                out.append(el.value)
            else:
                out.append(None)
        return tuple(out)
    return None


def int_const(node: ast.AST) -> int | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    return None
