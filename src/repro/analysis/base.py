"""Rule base class, violation record, and the rule registry.

A rule sees the whole corpus at once (``check(ctx)``) rather than one
file at a time because every interesting invariant here is
cross-module: hot-path slices, donation flows, and paged-leaf coverage
all need the call graph.  File-local rules simply loop over
``ctx.files``.
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis.astutil import SourceFile
from repro.analysis.callgraph import CallGraph


@dataclasses.dataclass(frozen=True)
class Violation:
    rule_id: str
    path: str                # file path as given on the command line
    line: int
    col: int
    message: str
    suppressed: bool = False
    justified: bool = False  # suppression comment carried a justification

    def format(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"[{self.rule_id}] {self.message}{tag}")


class AnalysisContext:
    """Everything a rule may consult: parsed files + the call graph."""

    def __init__(self, files: list[SourceFile]):
        self.files = files
        self.graph = CallGraph(files)

    def parsed(self) -> list[SourceFile]:
        return [f for f in self.files if f.tree is not None]


class Rule:
    """Subclass, set ``rule_id``/``description``, implement ``check``."""

    rule_id: str = ""
    description: str = ""

    def check(self, ctx: AnalysisContext) -> list[Violation]:
        raise NotImplementedError

    # helper: build a violation, folding in suppression state
    def violation(self, sf: SourceFile, node: ast.AST | None,
                  message: str, line: int | None = None,
                  col: int | None = None) -> Violation:
        ln = line if line is not None else getattr(node, "lineno", 1)
        cl = col if col is not None else getattr(node, "col_offset", 0)
        suppressed = sf.suppressed(ln, self.rule_id)
        justified = False
        if suppressed:
            justified = _has_justification(sf, ln, self.rule_id)
        return Violation(rule_id=self.rule_id, path=str(sf.path),
                         line=ln, col=cl, message=message,
                         suppressed=suppressed, justified=justified)


def _has_justification(sf: SourceFile, line: int, rule_id: str) -> bool:
    """True when the suppression comment carries trailing text after
    the closing bracket (the one-line justification convention)."""
    from repro.analysis.astutil import SUPPRESS_RE
    lines = sf.text.splitlines()
    for ln in (line, line - 1):
        if 1 <= ln <= len(lines):
            m = SUPPRESS_RE.search(lines[ln - 1])
            if m and (rule_id in m.group(1) or "*" in m.group(1)):
                return bool(lines[ln - 1][m.end():].strip())
    return False


_REGISTRY: dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    if not rule.rule_id:
        raise ValueError("rule_id must be set")
    _REGISTRY[rule.rule_id] = rule
    return rule


def all_rules() -> dict[str, Rule]:
    # import rule modules lazily so registration happens exactly once
    from repro.analysis import (  # noqa: F401
        rules_syntax, rules_hotpath, rules_donation,
        rules_retrace, rules_paging, rules_tiles)
    return dict(_REGISTRY)
