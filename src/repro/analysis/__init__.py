"""repro.analysis — static invariant checking for the serving hot path.

A stdlib-``ast`` analyzer (no runtime imports of the checked code, no
new dependencies) that turns the repo's dynamic serving invariants —
one host sync per quantum, donated-buffer discipline, zero post-warmup
retraces, paged-leaf coverage, atomic tile-table swaps — into CI-gated
static rules.  See ``docs/ARCHITECTURE.md`` §11 for the rule catalog
and suppression syntax; the CLI lives at ``tools/check_static.py``.

Suppress a finding in place with::

    # veltair: ignore[rule-id] one-line justification
"""
from repro.analysis.base import (AnalysisContext, Rule, Violation,
                                 all_rules, register)
from repro.analysis.runner import Report, iter_python_files, run

__all__ = ["AnalysisContext", "Rule", "Violation", "all_rules",
           "register", "Report", "iter_python_files", "run"]
