"""Rule 0 — ``syntax``: every scanned file must parse.

Replaces the bare ``python -m compileall`` CI step: a file that fails
``ast.parse`` is reported as a violation at the error's position.  This
rule ignores suppression comments (an unparseable file cannot be
trusted to carry them).
"""
from __future__ import annotations

from repro.analysis.base import AnalysisContext, Rule, Violation, register


class SyntaxRule(Rule):
    rule_id = "syntax"
    description = "file must parse with ast.parse (replaces compileall)"

    def check(self, ctx: AnalysisContext) -> list[Violation]:
        out: list[Violation] = []
        for sf in ctx.files:
            if sf.error is not None:
                out.append(Violation(
                    rule_id=self.rule_id, path=str(sf.path),
                    line=sf.error.lineno or 1,
                    col=(sf.error.offset or 1) - 1,
                    message=f"syntax error: {sf.error.msg}"))
        return out


register(SyntaxRule())
