"""Rule 1 — ``host-sync-in-hot-path``.

The serving contract (PR 4 onward) is *one* host sync per quantum: the
single ``np.asarray(handle.block)`` in ``finish_quantum`` (and the one
argmax coercion per admission).  Anything else that forces a
device→host transfer inside the quantum hot path — ``.item()``,
``int()/float()/bool()`` on a device value, ``np.asarray`` of a device
value, ``jax.device_get``, ``.block_until_ready()``, or an implicit
``if tracer:`` truth test — serializes the pipeline and destroys the
co-location win the paper measures.

The hot path is the call-graph slice rooted at the serving entry
points below.  Calls inside nested ``def``s (the jit closures in
``VersionCache``) are attributed to their outer function, so traced
model code is audited too.  Sanctioned syncs carry
``# veltair: ignore[host-sync-in-hot-path] <why>`` at the site.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.base import AnalysisContext, Rule, Violation, register

# Call-graph roots: matched by qualname suffix so fixture-sized repros
# (a mini ServingEngine in one file) slice the same way the repo does.
HOT_ROOTS = (
    "ServingEngine.begin_quantum",
    "ServingEngine.step_quantum",
    "ServingEngine.finish_quantum",
    "ServingEngine.prefill_step",
    "ServingEngine.admit_request",
    "VersionCache.get",
    "VersionCache.quantum",
    "VersionCache.spec_quantum",
    "OnlineRuntime.serve",
    "ClusterRuntime.serve",
)

# Attribute access on these never yields a device value.
_METADATA_ATTRS = {"shape", "dtype", "ndim", "size", "weak_type",
                   "sharding", "itemsize", "nbytes"}

# numpy aliases whose calls produce *host* values (and whose asarray/
# array calls on device values are sinks).
_NP_HEADS = {"np", "numpy"}


def _is_jax_array_annotation(ann: ast.AST | None) -> bool:
    """Does the annotation mention ``jax.Array`` (possibly in a union)?"""
    if ann is None:
        return False
    for node in ast.walk(ann):
        if astutil.dotted_name(node) == "jax.Array":
            return True
        if isinstance(node, ast.Constant) and isinstance(node.value, str) \
                and "jax.Array" in node.value:
            return True
    return False


def device_attr_names(ctx: AnalysisContext) -> set[str]:
    """Attribute names annotated ``jax.Array`` anywhere in the corpus
    (e.g. ``QuantumHandle.block``) — reading them yields device values."""
    out: set[str] = set()
    for sf in ctx.parsed():
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.AnnAssign) and isinstance(
                    node.target, ast.Name):
                if _is_jax_array_annotation(node.annotation):
                    out.add(node.target.id)
    return out


class TaintScan:
    """Forward may-taint pass over one function body.  ``tainted`` holds
    local names bound to device values; expression taint is recomputed
    structurally on demand."""

    def __init__(self, device_attrs: set[str]):
        self.device_attrs = device_attrs
        self.tainted: set[str] = set()

    def expr_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _METADATA_ATTRS:
                return False
            if node.attr in self.device_attrs:
                return True
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Call):
            name = astutil.dotted_name(node.func) or ""
            head = name.split(".")[0]
            if head in _NP_HEADS:
                return False           # numpy results live on host
            if head in {"jnp", "jax", "lax"} or name.startswith(
                    "jax.numpy"):
                return name != "jax.device_get"
            if head in {"int", "float", "bool", "len", "range", "str"}:
                return False           # host coercions (the sinks)
            if isinstance(node.func, ast.Attribute):
                # method call: logits.max(), handle.block.astype(...)
                if node.func.attr in {"item", "tolist", "block_until_ready"}:
                    return False       # these land on host
                if self.expr_tainted(node.func.value):
                    return True
            return any(self.expr_tainted(a) for a in node.args)
        if isinstance(node, (ast.BinOp,)):
            return self.expr_tainted(node.left) or self.expr_tainted(
                node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr_tainted(node.operand)
        if isinstance(node, ast.Compare):
            return self.expr_tainted(node.left) or any(
                self.expr_tainted(c) for c in node.comparators)
        if isinstance(node, ast.IfExp):
            return self.expr_tainted(node.body) or self.expr_tainted(
                node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr_tainted(node.value)
        if isinstance(node, ast.NamedExpr):
            return self.expr_tainted(node.value)
        return False

    def _bind(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind(el, tainted)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted)

    def seed_params(self, fn: ast.AST) -> None:
        args = fn.args  # type: ignore[union-attr]
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)):
            if _is_jax_array_annotation(a.annotation):
                self.tainted.add(a.arg)

    def run(self, fn: ast.AST) -> None:
        """Two forward passes so loop-carried taint stabilizes."""
        self.seed_params(fn)
        for _ in range(2):
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign):
                    t = self.expr_tainted(node.value)
                    for tgt in node.targets:
                        self._bind(tgt, t)
                elif isinstance(node, ast.AnnAssign) and node.value:
                    t = self.expr_tainted(node.value) or \
                        _is_jax_array_annotation(node.annotation)
                    self._bind(node.target, t)
                elif isinstance(node, ast.AugAssign):
                    if self.expr_tainted(node.value):
                        self._bind(node.target, True)
                elif isinstance(node, ast.For):
                    self._bind(node.target, self.expr_tainted(node.iter))


class HostSyncRule(Rule):
    rule_id = "host-sync-in-hot-path"
    description = ("no device→host transfer inside the quantum hot path "
                   "(one sanctioned sync per quantum)")

    def check(self, ctx: AnalysisContext) -> list[Violation]:
        device_attrs = device_attr_names(ctx)
        roots: list[str] = []
        for suffix in HOT_ROOTS:
            roots.extend(ctx.graph.find(suffix))
        hot = ctx.graph.reachable(roots)
        out: list[Violation] = []
        for qual in sorted(hot):
            info = ctx.graph.functions[qual]
            scan = TaintScan(device_attrs)
            scan.run(info.node)
            out.extend(self._scan_sinks(info.sf, info.node, scan, qual))
        return out

    def _scan_sinks(self, sf, fn, scan: TaintScan,
                    qual: str) -> list[Violation]:
        out: list[Violation] = []
        where = f"in hot path ({qual.split(':', 1)[1]})"
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = astutil.dotted_name(node.func) or ""
                if isinstance(node.func, ast.Attribute):
                    meth = node.func.attr
                    if meth == "item" and scan.expr_tainted(node.func.value):
                        out.append(self.violation(
                            sf, node, f".item() forces a device→host "
                            f"sync {where}"))
                        continue
                    if meth == "block_until_ready":
                        out.append(self.violation(
                            sf, node, f".block_until_ready() blocks the "
                            f"dispatch pipeline {where}"))
                        continue
                if name in {"int", "float", "bool"} and node.args and \
                        scan.expr_tainted(node.args[0]):
                    out.append(self.violation(
                        sf, node, f"{name}() coercion of a device value "
                        f"forces a host sync {where}"))
                    continue
                if name in {"np.asarray", "np.array", "numpy.asarray",
                            "numpy.array"} and node.args and \
                        scan.expr_tainted(node.args[0]):
                    out.append(self.violation(
                        sf, node, f"{name}() of a device value forces a "
                        f"device→host transfer {where}"))
                    continue
                if name == "jax.device_get":
                    out.append(self.violation(
                        sf, node, f"jax.device_get() transfers to host "
                        f"{where}"))
                    continue
            elif isinstance(node, (ast.If, ast.While)):
                test = node.test
                if isinstance(test, ast.UnaryOp) and isinstance(
                        test.op, ast.Not):
                    test = test.operand
                if isinstance(test, (ast.Name, ast.Attribute)) and \
                        scan.expr_tainted(test):
                    out.append(self.violation(
                        sf, node, f"truth test of a device value "
                        f"implicitly syncs {where}", line=test.lineno,
                        col=test.col_offset))
            elif isinstance(node, ast.Assert):
                if scan.expr_tainted(node.test):
                    out.append(self.violation(
                        sf, node, f"assert on a device value implicitly "
                        f"syncs {where}"))
        return out


register(HostSyncRule())
