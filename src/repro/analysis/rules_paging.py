"""Rule 4 — ``paged-leaf-coverage``.

PR 7's paging contract: ``Model.paged_leaf_paths`` derives the set of
pageable leaves from ``Model.cache_specs`` by looking for a ``"seq"``
axis in each leaf's ``ParamSpec``.  That derivation only sees specs
that ``cache_specs`` actually returns — a new cache family whose spec
helper isn't wired into ``cache_specs`` would allocate dense
``max_len`` rows, silently bypass paging, and reintroduce the memory
wall the paged cache removed.

The rule therefore checks, over the scanned corpus:

* every function that (a) has ``cache`` in its name and (b) constructs
  a ``ParamSpec`` with a literal ``"seq"`` axis must be **reachable
  from** the ``Model.cache_specs`` anchor in the call graph;
* every function named ``*cache_spec*`` must be **connected** to the
  anchor (reachable from it, or a transitive caller of it — e.g.
  ``paged_cache_specs`` *calls* ``cache_specs``).

When no ``Model.cache_specs`` anchor exists in the scanned set (single
file runs, unrelated fixtures) the rule is inert — coverage is only
checkable against the anchor.
"""
from __future__ import annotations

import ast

from repro.analysis import astutil
from repro.analysis.base import AnalysisContext, Rule, Violation, register

ANCHOR_SUFFIX = "Model.cache_specs"


def _seq_paramspec_calls(fn: ast.AST) -> list[ast.Call]:
    """ParamSpec(...) calls inside ``fn`` whose axes literal has "seq"."""
    out = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = astutil.dotted_name(node.func) or ""
        if name.split(".")[-1] != "ParamSpec":
            continue
        axes = None
        for kw in node.keywords:
            if kw.arg == "axes":
                axes = kw.value
        if axes is None and len(node.args) >= 3:
            axes = node.args[2]
        if axes is None:
            continue
        tup = astutil.const_str_tuple(axes)
        if tup and "seq" in tup:
            out.append(node)
    return out


class PagedLeafRule(Rule):
    rule_id = "paged-leaf-coverage"
    description = ("every 'seq'-axis cache ParamSpec must be reachable "
                   "from Model.cache_specs (paged_leaf_paths contract)")

    def check(self, ctx: AnalysisContext) -> list[Violation]:
        anchors = ctx.graph.find(ANCHOR_SUFFIX)
        if not anchors:
            return []
        reachable = ctx.graph.reachable(anchors)
        connected = ctx.graph.connected(anchors)
        out: list[Violation] = []
        for qual, info in sorted(ctx.graph.functions.items()):
            fname = info.node.name  # type: ignore[union-attr]
            if "cache" in fname and qual not in reachable:
                calls = _seq_paramspec_calls(info.node)
                if calls:
                    out.append(self.violation(
                        info.sf, calls[0],
                        f"{fname}() constructs a \"seq\"-axis cache "
                        f"ParamSpec but is not reachable from "
                        f"Model.cache_specs — its leaves bypass "
                        f"paged_leaf_paths and stay dense (PR 7 paging "
                        f"contract)"))
                    continue
            if "cache_spec" in fname and qual not in connected and \
                    qual not in set(anchors):
                out.append(self.violation(
                    info.sf, info.node,
                    f"{fname}() looks like a cache-spec helper but is "
                    f"disconnected from Model.cache_specs — wire it into "
                    f"the cache_specs dispatch so paging sees its leaves"))
        return out


register(PagedLeafRule())
