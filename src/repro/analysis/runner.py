"""File discovery and the analysis driver.

``run(paths)`` loads every ``.py`` under the given paths, builds one
:class:`AnalysisContext` (so cross-file rules see the whole corpus at
once), executes the registered rules, and splits results into active
violations and suppressed ones (for ``--json`` and the summary line).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from repro.analysis import astutil
from repro.analysis.base import AnalysisContext, Violation, all_rules

_SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "build", "dist",
              ".eggs", "node_modules"}


def module_name(path: pathlib.Path) -> str:
    """Dotted module name derived from the package structure on disk:
    walk up while ``__init__.py`` exists; loose scripts get
    ``<parentdir>.<stem>``."""
    parts = [path.stem] if path.stem != "__init__" else []
    cur = path.parent
    seen_pkg = False
    while (cur / "__init__.py").exists():
        parts.insert(0, cur.name)
        seen_pkg = True
        cur = cur.parent
    if not seen_pkg:
        parts.insert(0, path.parent.name)
    return ".".join(p for p in parts if p) or path.stem


def iter_python_files(paths: list[str]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_file() and p.suffix == ".py":
            out.append(p)
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in _SKIP_DIRS for part in f.parts):
                    out.append(f)
    return out


@dataclasses.dataclass
class Report:
    violations: list[Violation]      # active (fail the run)
    suppressed: list[Violation]
    files_scanned: int
    rules_run: list[str]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> str:
        def rec(v: Violation) -> dict:
            return {"file": v.path, "line": v.line, "col": v.col,
                    "rule": v.rule_id, "message": v.message,
                    "suppressed": v.suppressed,
                    "justified": v.justified}
        return json.dumps({
            "ok": self.ok,
            "files_scanned": self.files_scanned,
            "rules": self.rules_run,
            "violations": [rec(v) for v in self.violations],
            "suppressed": [rec(v) for v in self.suppressed],
        }, indent=2)

    def summary(self) -> str:
        return (f"check_static: {self.files_scanned} files, "
                f"{len(self.rules_run)} rules, "
                f"{len(self.violations)} violation(s), "
                f"{len(self.suppressed)} suppressed")


def run(paths: list[str], rule_ids: list[str] | None = None) -> Report:
    files = [astutil.load_file(p, module_name(p))
             for p in iter_python_files(paths)]
    ctx = AnalysisContext(files)
    rules = all_rules()
    if rule_ids:
        unknown = set(rule_ids) - set(rules)
        if unknown:
            raise KeyError(
                f"unknown rule id(s): {', '.join(sorted(unknown))}; "
                f"known: {', '.join(sorted(rules))}")
        rules = {rid: rules[rid] for rid in rule_ids}
    active: list[Violation] = []
    suppressed: list[Violation] = []
    for rid in sorted(rules):
        for v in rules[rid].check(ctx):
            (suppressed if v.suppressed else active).append(v)
    key = (lambda v: (v.path, v.line, v.col, v.rule_id))
    return Report(violations=sorted(active, key=key),
                  suppressed=sorted(suppressed, key=key),
                  files_scanned=len(files),
                  rules_run=sorted(rules))
