"""Distributed-execution support: logical-axis sharding rules, optimizer
state sharding, and pod-scale fault tolerance primitives."""
