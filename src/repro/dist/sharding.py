"""Logical-axis -> mesh-axis sharding rules and pspec derivation.

Models declare *logical* axes on every tensor (repro.models.params); a
:class:`RuleSet` maps those names onto mesh axes for one execution mode.
``pspec_for`` turns (shape, axes, rules, mesh) into a ``PartitionSpec``,
enforcing:

  * divisibility — a mesh axis whose size does not divide the dim is not
    used (a tuple rule keeps the longest prefix whose product divides);
  * single use — each mesh axis appears at most once per spec;
  * GQA TP fallback — when a tensor-parallel (scalar) rule exists but the
    dim cannot shard over it (e.g. kv_heads=8 on model=16), the whole
    tensor falls back to plain data-parallel sharding: the model axis is
    everywhere replicated and only data-family axes survive, collapsed to
    their scalar form.

``hint`` is the in-model annotation point: a no-op outside a
``use_rules`` context, a ``with_sharding_constraint`` inside one — so the
same model code runs unsharded on one CPU device and sharded on the pod.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def _is_param_spec(x) -> bool:
    # structural check: repro.models imports this module, so importing
    # ParamSpec here would be circular
    return hasattr(x, "axes") and hasattr(x, "shape") and hasattr(x, "dtype")


# data-family mesh axes (pure replication of the batch): the GQA fallback
# keeps these and drops tensor-parallel axes
DATA_AXES = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class RuleSet:
    """One mode's rule tables: ``params`` for weights/optimizer state,
    ``acts`` for activations and caches."""
    name: str
    params: dict[str, Any]
    acts: dict[str, Any]


def make_rules(mode: str, multi_pod: bool = False,
               seq_parallel: bool = False) -> RuleSet:
    """Rule tables for ``mode`` in {"train", "serve"}.

    Weights: FSDP over the data family + tensor parallel over "model".
    Activations: batch over the data family; logits vocab over "model".
    ``seq_parallel`` additionally shards activation/cache sequence axes
    over "model" (context-parallel decode for kv_heads=1 archs, where the
    model axis is otherwise idle)."""
    params = {
        "embed": ("pod", "data"),
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "expert": "model",
        "kv_lora": "model",
        "inner": "model",
    }
    acts = {
        "batch": ("pod", "data"),
        "groups": ("pod", "data"),
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "expert": "model",
    }
    if seq_parallel:
        acts["seq"] = "model"
    _ = multi_pod  # the "pod" axis is simply absent from single-pod meshes
    return RuleSet(name=mode, params=params, acts=acts)


def _axis_size(mesh, name: str) -> int | None:
    try:
        return mesh.shape[name]
    except (KeyError, TypeError):
        return None


def pspec_for(shape: tuple[int, ...], axes: tuple, rules: dict,
              mesh) -> P:
    """PartitionSpec for one tensor under ``rules`` on ``mesh``."""
    entries: list = []
    used: set[str] = set()
    tp_dropped = False
    for dim, ax in zip(shape, axes):
        rule = rules.get(ax) if ax is not None else None
        if rule is None:
            entries.append(None)
            continue
        if isinstance(rule, str):
            size = _axis_size(mesh, rule)
            if size and rule not in used and dim % size == 0:
                entries.append(rule)
                used.add(rule)
            else:
                entries.append(None)
                if size and rule not in used:
                    tp_dropped = True       # axis exists but cannot divide
        else:                               # tuple rule: product sharding
            sel: list[str] = []
            prod = 1
            for r in rule:
                size = _axis_size(mesh, r)
                if not size or r in used:
                    continue
                if dim % (prod * size) != 0:
                    break                   # drop trailing axes
                sel.append(r)
                prod *= size
            used.update(sel)
            entries.append(tuple(sel) if sel else None)
    if tp_dropped:
        # GQA TP fallback: replicate over the unusable tensor-parallel
        # axis; keep only data-family sharding, in scalar form.
        out: list = []
        for e in entries:
            if isinstance(e, tuple):
                kept = [a for a in e if a in DATA_AXES]
                e = kept[0] if len(kept) == 1 else (tuple(kept) or None)
            elif e is not None and e not in DATA_AXES:
                e = None
            out.append(e)
        entries = out
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_pspecs(specs, rules: RuleSet, mesh):
    """PartitionSpec tree for a ParamSpec tree under the params rules."""
    return jax.tree_util.tree_map(
        lambda s: pspec_for(s.shape, s.axes, rules.params, mesh),
        specs, is_leaf=_is_param_spec)


def shardings_of(pspecs, mesh):
    """NamedSharding tree from a PartitionSpec tree."""
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, ps),
        pspecs, is_leaf=lambda x: isinstance(x, P))


def device_bytes(pspecs, specs, mesh) -> int:
    """Total per-device parameter bytes under the given pspecs."""
    ps_leaves = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    sp_leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_param_spec)
    total = 0
    for ps, sp in zip(ps_leaves, sp_leaves):
        shards = 1
        for entry in ps:
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                if a is not None:
                    shards *= mesh.shape[a]
        total += sp.size * jnp.dtype(sp.dtype).itemsize // shards
    return total


# --------------------------------------------------------------------------
# hint: in-model sharding annotations
# --------------------------------------------------------------------------
_ACTIVE: list[tuple[Any, RuleSet]] = []


@contextlib.contextmanager
def use_rules(mesh, rules: RuleSet):
    """Activate (mesh, rules) so ``hint`` becomes a sharding constraint."""
    _ACTIVE.append((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.pop()


def hint(x: jax.Array, axes: tuple) -> jax.Array:
    """Constrain ``x`` to the active rules' sharding; no-op outside a
    ``use_rules`` context (single-device smoke tests, serving engine)."""
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    spec = pspec_for(x.shape, axes, rules.acts, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
