"""Optimizer-state ParamSpecs (for dry-run sharding without allocation).

Mirrors ``repro.training.optimizer.init_opt_state`` structurally: every
state tensor inherits its parameter's logical axes, so FSDP/TP rules
apply transparently.  Adafactor's factored statistics drop the reduced
axis (vr drops the last, vc the second-to-last)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec
from repro.training.optimizer import OptimizerConfig, _factored

PyTree = Any


def _f32(spec: ParamSpec) -> ParamSpec:
    return ParamSpec(spec.shape, jnp.float32, spec.axes, init="zeros")


def _map(specs: PyTree, fn) -> PyTree:
    return jax.tree_util.tree_map(
        fn, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def opt_state_specs(cfg: OptimizerConfig, param_specs: PyTree) -> dict:
    """ParamSpec tree matching ``init_opt_state(cfg, params)``."""
    master = _map(param_specs, _f32)
    if cfg.name == "adafactor":
        def stat(sp: ParamSpec):
            if _factored(sp.shape, cfg.min_dim_factored):
                return {
                    "vr": ParamSpec(sp.shape[:-1], jnp.float32,
                                    sp.axes[:-1], init="zeros"),
                    "vc": ParamSpec(sp.shape[:-2] + sp.shape[-1:],
                                    jnp.float32,
                                    sp.axes[:-2] + sp.axes[-1:],
                                    init="zeros"),
                }
            return {"v": _f32(sp)}
        return {"stats": _map(param_specs, stat), "master": master}
    return {"mu": _map(param_specs, _f32), "nu": _map(param_specs, _f32),
            "master": master}


def train_state_specs(cfg: OptimizerConfig, param_specs: PyTree) -> dict:
    """ParamSpec tree matching ``init_train_state`` (sans error-feedback:
    the dry-run never lowers int8 gradient compression)."""
    return {
        "opt": opt_state_specs(cfg, param_specs),
        "step": ParamSpec((), jnp.int32, (), init="zeros"),
    }
