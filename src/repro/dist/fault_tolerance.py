"""Pod-scale fault tolerance primitives: worker heartbeats + straggler
detection (the serving simulator charges the same bounded detect+redo
cost; see serving.simulator straggler mitigation)."""
from __future__ import annotations

import dataclasses


class HeartbeatMonitor:
    """Tracks worker liveness from periodic beats; ``sweep`` evicts
    workers whose last beat is older than the deadline."""

    def __init__(self, deadline_s: float):
        self.deadline_s = deadline_s
        self._last: dict[int, float] = {}

    def beat(self, worker: int, now: float) -> None:
        self._last[worker] = now

    def sweep(self, now: float) -> list[int]:
        """Evict and return workers that missed the deadline."""
        dead = sorted(w for w, t in self._last.items()
                      if now - t > self.deadline_s)
        for w in dead:
            del self._last[w]
        return dead

    def alive(self) -> list[int]:
        return sorted(self._last)


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    """A chunk exceeding ``factor`` x its predicted latency is a straggler;
    the redo cost is the full detection window plus one re-execution."""
    factor: float = 4.0

    def is_straggler(self, predicted_s: float, elapsed_s: float) -> bool:
        return elapsed_s > self.factor * predicted_s

    def redo_cost(self, predicted_s: float) -> float:
        return self.factor * predicted_s + predicted_s
