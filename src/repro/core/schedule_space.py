"""Schedule-space enumeration (stands in for the Ansor search pass).

For a GEMM-reduced layer we enumerate (bm, bk, bn, unroll) candidates,
compute the paper's two metrics — parallelism (independent tiles x unroll)
and locality (blocking size in bytes) — and the traffic model the cost model
consumes.  The paper runs ~1024 auto-scheduler iterations per layer; our
space is the same knob set enumerated exhaustively (it is small enough), so
"single pass" here means exactly what Alg. 1 needs: one enumeration serving
all interference levels.
"""
from __future__ import annotations

import math
from typing import Iterable

from repro.core.cost_model import CodeVersion, GemmLayer, HardwareSpec

TILES = (32, 64, 128, 256, 512, 1024, 2048)
UNROLLS = (1, 2, 4)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _clip_tiles(dim: int, tiles: Iterable[int]) -> list[int]:
    out = sorted({min(t, dim) for t in tiles})
    return out


def enumerate_versions(layer: GemmLayer, hw: HardwareSpec,
                       tiles: Iterable[int] = TILES,
                       unrolls: Iterable[int] = UNROLLS) -> list[CodeVersion]:
    """All tile/unroll candidates whose working set fits the private cache."""
    out: list[CodeVersion] = []
    it = layer.itemsize
    m, k, n = layer.m, layer.k, layer.n
    # CPU: tiles may target the LLC (that *is* the locality knob the paper
    # searches over); TPU: tiles must fit VMEM, hard constraint.
    tile_limit = (hw.shared_cache_bytes * 0.5 if hw.cache_shared
                  else hw.private_cache_bytes)

    def blocked_traffic(tm, tk, tn):
        # A panel re-read per N-tile, B panel per M-tile, C streamed
        return it * (m * k * _ceil_div(n, tn) + k * n * _ceil_div(m, tm)
                     + 2 * m * n)

    # reuse-collapse bound: L1-resident micro-tiles survive eviction
    # (calibrated so the most vulnerable version degrades ~7x, Fig. 6a)
    naive_all = blocked_traffic(min(16, m), k, min(16, n))
    for bm in _clip_tiles(m, tiles):
        for bk in _clip_tiles(k, tiles):
            for bn in _clip_tiles(n, tiles):
                tile_bytes = (bm * bk + bk * bn) * it + bm * bn * 4
                if tile_bytes > tile_limit:
                    continue
                n_tiles = _ceil_div(m, bm) * _ceil_div(n, bn)
                mem = blocked_traffic(bm, bk, bn)
                naive = max(naive_all, mem)
                for u in unrolls:
                    # unroll widens ILP (parallelism metric); compute
                    # efficiency grows with tile size (deeper pipelining /
                    # MXU utilization) — this is why the solo-optimal
                    # version is a big-tile one (paper Fig. 6a impl-1).
                    eff = hw.eff_base + hw.eff_slope * math.log2(
                        max(tile_bytes, 1024) / 65536.0)
                    eff = min(max(eff, hw.eff_min), hw.eff_max)
                    eff = min(eff + 0.02 * math.log2(u), hw.eff_max + 0.05)
                    out.append(CodeVersion(
                        layer_name=layer.name, bm=bm, bk=bk, bn=bn, unroll=u,
                        parallelism=n_tiles * u,
                        tile_bytes=tile_bytes,
                        flops=layer.flops,
                        mem_bytes=float(mem),
                        naive_bytes=float(naive),
                        resident_bytes=float(layer.io_bytes),
                        comm_bytes_per_unit=layer.comm_bytes_per_unit,
                        mxu_efficiency=eff,
                    ))
    return out


def default_version(layer: GemmLayer, hw: HardwareSpec) -> CodeVersion:
    """The 'solo-tuned' version: best at zero interference (TVM default)."""
    from repro.core.cost_model import Interference, latency
    vs = enumerate_versions(layer, hw)
    return min(vs, key=lambda v: latency(hw, v, hw.n_units, Interference()))
