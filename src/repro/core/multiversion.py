"""Alg. 1 — single-pass static multi-version compilation.

Steps (paper §4.1):
  1. collect candidate implementations from one enumeration pass
     (schedule_space), computing parallelism/locality metrics;
  2. filter out candidates that cannot meet the layer's QoS slice even
     solo (minimum-FLOPS filter);
  3. ExtractDominant: keep the Pareto frontier of (parallelism, locality) —
     no retained version is dominated on both metrics;
  4. pick V (default 5) versions uniformly along the frontier sorted by
     blocking size; then prune versions whose removal keeps performance
     within 90% of the full set across all interference levels (the
     storage-reduction rule: >80% of layers end up with <=3).

The result is a ``VersionSet`` with a precomputed interference-level ->
version table (the runtime scheduler just indexes it).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from repro.core import cost_model as cm
from repro.core import schedule_space as ss

V_MAX = 5                 # paper: empirically best (Fig. 14b)
RETENTION = 0.90          # keep perf within 90% of full set

LADDER_SCHEMA = 1         # LadderSpec JSON schema version


def _matmul_bytes(tiles: dict, itemsize: int = 4) -> int:
    """Working set of a level's matmul tiling — the exclusive<->shared
    ordering metric (A and B panels at ``itemsize``, f32 accumulator)."""
    kw = tiles["matmul"]
    bm, bk, bn = int(kw["bm"]), int(kw["bk"]), int(kw["bn"])
    return (bm * bk + bk * bn) * itemsize + bm * bn * 4


@dataclasses.dataclass
class LadderSpec:
    """An autotuned interference-level -> tile-table ladder.

    One entry per grid level (``cm.NUM_LEVELS``): level 0 is the
    exclusive end (big tiles, maximal shared-cache reuse), the last level
    the shared end (small private-cache-resident tiles that cede the
    LLC).  The spec is the serialized artifact of
    ``tools/autotune_ladder.py``: emitted as JSON, loaded/installed by
    :mod:`repro.kernels.dispatch`, consumed by ``ServingEngine(ladder=)``
    in place of the hand-written ``DEFAULT_LEVEL_TILES``, and prebuilt by
    ``VersionCache.warmup`` so every level switch stays a dictionary
    swap.  ``scores`` carries the search's predicted latency per level at
    that level's grid pressure (observability; not used online)."""
    name: str
    hw: str                                  # HardwareSpec.name it was tuned on
    levels: list                             # grid idx -> {op: tiling kwargs}
    scores: list = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.levels)

    def tiles_for_level(self, level: float) -> dict:
        lvl = self.levels[cm.level_to_idx(level)]
        return {op: dict(kw) for op, kw in lvl.items()}

    def tile_tables(self) -> list:
        """Distinct tile tables in level order (warmup's build list)."""
        seen, out = set(), []
        for lvl in self.levels:
            key = tuple(sorted((op, tuple(sorted(kw.items())))
                               for op, kw in lvl.items()))
            if key not in seen:
                seen.add(key)
                out.append({op: dict(kw) for op, kw in lvl.items()})
        return out

    def validate(self) -> None:
        """Structural + ordering invariants.  Raises ValueError unless
        the spec has exactly one complete matmul tiling per grid level
        and the matmul working set is non-increasing from the exclusive
        end to the shared end (the spectrum ordering the scheduler's
        monotone level index assumes)."""
        if len(self.levels) != cm.NUM_LEVELS:
            raise ValueError(f"ladder has {len(self.levels)} levels, "
                             f"expected {cm.NUM_LEVELS}")
        sizes = []
        for i, lvl in enumerate(self.levels):
            kw = lvl.get("matmul")
            if not kw or any(k not in kw for k in ("bm", "bk", "bn")):
                raise ValueError(f"level {i} has no complete matmul "
                                 f"tiling: {lvl!r}")
            if any(int(kw[k]) < 1 for k in ("bm", "bk", "bn")):
                raise ValueError(f"level {i} has non-positive tiles: {kw!r}")
            sizes.append(_matmul_bytes(lvl))
        for i in range(1, len(sizes)):
            if sizes[i] > sizes[i - 1]:
                raise ValueError(
                    f"ladder ordering violated: level {i} working set "
                    f"{sizes[i]}B > level {i - 1}'s {sizes[i - 1]}B — "
                    "levels must walk exclusive (big tiles) -> shared "
                    "(small tiles)")

    # -- serialization -----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({"schema": LADDER_SCHEMA, "name": self.name,
                           "hw": self.hw, "levels": self.levels,
                           "scores": self.scores, "meta": self.meta},
                          indent=1, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "LadderSpec":
        data = json.loads(text)
        if data.get("schema") != LADDER_SCHEMA:
            raise ValueError(f"unsupported ladder schema "
                             f"{data.get('schema')!r} (want {LADDER_SCHEMA})")
        spec = LadderSpec(name=data["name"], hw=data["hw"],
                          levels=data["levels"],
                          scores=data.get("scores", []),
                          meta=data.get("meta", {}))
        spec.validate()
        return spec

    def save(self, path) -> pathlib.Path:
        p = pathlib.Path(path)
        self.validate()
        p.write_text(self.to_json())
        return p

    @staticmethod
    def load(path) -> "LadderSpec":
        return LadderSpec.from_json(pathlib.Path(path).read_text())


def extract_dominant(impls: list[cm.CodeVersion]) -> list[cm.CodeVersion]:
    """Pareto-maximal set on (parallelism, locality).

    A version is dominated iff another has >= parallelism AND >= locality
    (with at least one strict).  Classic sweep: sort by parallelism desc,
    keep strictly increasing locality."""
    if not impls:
        return []
    ordered = sorted(impls, key=lambda v: (-v.parallelism, -v.locality))
    out: list[cm.CodeVersion] = []
    best_loc = -1.0
    for v in ordered:
        if v.locality > best_loc:
            out.append(v)
            best_loc = v.locality
    return out


def _best_latency_table(hw: cm.HardwareSpec, versions: list[cm.CodeVersion],
                        units: int) -> list[float]:
    return [min(cm.latency(hw, v, units, itf) for v in versions)
            for itf in cm.level_grid()]


SWITCH_MARGIN = 1.25   # only leave the solo winner for >25% predicted gain


def _select_by_level(hw: cm.HardwareSpec, versions: list[cm.CodeVersion],
                     units: int) -> list[int]:
    """Per-level version table.  Conservative under proxy noise: stay on
    the zero-interference winner unless a challenger is predicted to beat
    it by SWITCH_MARGIN at that level."""
    grid = cm.level_grid()
    lat0 = [cm.latency(hw, v, units, grid[0]) for v in versions]
    anchor = lat0.index(min(lat0))
    table = []
    for itf in grid:
        lats = [cm.latency(hw, v, units, itf) for v in versions]
        best = lats.index(min(lats))
        table.append(best if lats[anchor] > SWITCH_MARGIN * lats[best]
                     else anchor)
    return table


@dataclasses.dataclass
class VersionSet:
    layer_name: str
    versions: list[cm.CodeVersion]
    level_table: list[int]          # interference level idx -> version idx
    dominant_count: int             # |Pareto frontier| before selection
    candidate_count: int            # raw enumeration size

    def select(self, itf: cm.Interference) -> cm.CodeVersion:
        return self.versions[self.level_table[cm.level_to_idx(itf.level)]]

    def solo_version(self) -> cm.CodeVersion:
        return self.versions[self.level_table[0]]


def compile_layer(layer: cm.GemmLayer, hw: cm.HardwareSpec,
                  qos_budget_s: float | None = None, *,
                  v_max: int = V_MAX, retention: float = RETENTION,
                  ref_units: int | None = None) -> VersionSet:
    """Single-pass multi-version compilation for one layer."""
    ref_units = ref_units or max(hw.n_units // 4, 1)
    impls = ss.enumerate_versions(layer, hw)
    candidate_count = len(impls)

    # step 2: QoS filter (solo latency on all units must fit the budget)
    if qos_budget_s is not None:
        feasible = [v for v in impls
                    if cm.latency(hw, v, hw.n_units, cm.Interference())
                    <= qos_budget_s]
        if feasible:
            impls = feasible

    # step 3: Pareto frontier
    dom = extract_dominant(impls)
    dom.sort(key=lambda v: v.tile_bytes)

    # step 4a: pick V along the frontier — force-include the zero- and
    # max-interference winners (impl-1 / impl-4 of Fig. 6), fill uniformly
    if len(dom) <= v_max:
        picked = list(dom)
    else:
        grid = cm.level_grid()
        best0 = min(dom, key=lambda v: cm.latency(hw, v, ref_units, grid[0]))
        best9 = min(dom, key=lambda v: cm.latency(hw, v, ref_units, grid[-1]))
        forced = {dom.index(best0), dom.index(best9)}
        idxs = sorted(forced | {round(i * (len(dom) - 1) / (v_max - 1))
                                for i in range(v_max)})
        while len(idxs) > v_max:
            # drop a non-forced index, innermost first
            for i in idxs[1:-1]:
                if i not in forced:
                    idxs.remove(i)
                    break
            else:
                idxs = idxs[:v_max]
        picked = [dom[i] for i in idxs]

    # step 4b: redundancy pruning against the full-set latency envelope
    full_env = _best_latency_table(hw, picked, ref_units)
    keep = list(picked)
    changed = True
    while changed and len(keep) > 1:
        changed = False
        for v in sorted(keep, key=lambda v: -v.tile_bytes):
            trial = [w for w in keep if w is not v]
            env = _best_latency_table(hw, trial, ref_units)
            if all(e <= f / retention for e, f in zip(env, full_env)):
                keep = trial
                changed = True
                break

    keep.sort(key=lambda v: v.tile_bytes)
    return VersionSet(
        layer_name=layer.name,
        versions=keep,
        level_table=_select_by_level(hw, keep, ref_units),
        dominant_count=len(dom),
        candidate_count=candidate_count,
    )


def compile_model(layers: list[cm.GemmLayer], hw: cm.HardwareSpec,
                  model_qos_s: float | None = None,
                  **kw) -> list[VersionSet]:
    """Compile every layer; per-layer QoS slice proportional to its FLOPs
    (the paper's minimal-FLOPS-to-meet-model-latency rule)."""
    total = sum(l.flops for l in layers) or 1.0
    out = []
    for l in layers:
        budget = (model_qos_s * l.flops / total
                  if model_qos_s is not None else None)
        out.append(compile_layer(l, hw, budget, **kw))
    return out
