"""Alg. 1 — single-pass static multi-version compilation.

Steps (paper §4.1):
  1. collect candidate implementations from one enumeration pass
     (schedule_space), computing parallelism/locality metrics;
  2. filter out candidates that cannot meet the layer's QoS slice even
     solo (minimum-FLOPS filter);
  3. ExtractDominant: keep the Pareto frontier of (parallelism, locality) —
     no retained version is dominated on both metrics;
  4. pick V (default 5) versions uniformly along the frontier sorted by
     blocking size; then prune versions whose removal keeps performance
     within 90% of the full set across all interference levels (the
     storage-reduction rule: >80% of layers end up with <=3).

The result is a ``VersionSet`` with a precomputed interference-level ->
version table (the runtime scheduler just indexes it).
"""
from __future__ import annotations

import dataclasses

from repro.core import cost_model as cm
from repro.core import schedule_space as ss

V_MAX = 5                 # paper: empirically best (Fig. 14b)
RETENTION = 0.90          # keep perf within 90% of full set


def extract_dominant(impls: list[cm.CodeVersion]) -> list[cm.CodeVersion]:
    """Pareto-maximal set on (parallelism, locality).

    A version is dominated iff another has >= parallelism AND >= locality
    (with at least one strict).  Classic sweep: sort by parallelism desc,
    keep strictly increasing locality."""
    if not impls:
        return []
    ordered = sorted(impls, key=lambda v: (-v.parallelism, -v.locality))
    out: list[cm.CodeVersion] = []
    best_loc = -1.0
    for v in ordered:
        if v.locality > best_loc:
            out.append(v)
            best_loc = v.locality
    return out


def _best_latency_table(hw: cm.HardwareSpec, versions: list[cm.CodeVersion],
                        units: int) -> list[float]:
    return [min(cm.latency(hw, v, units, itf) for v in versions)
            for itf in cm.level_grid()]


SWITCH_MARGIN = 1.25   # only leave the solo winner for >25% predicted gain


def _select_by_level(hw: cm.HardwareSpec, versions: list[cm.CodeVersion],
                     units: int) -> list[int]:
    """Per-level version table.  Conservative under proxy noise: stay on
    the zero-interference winner unless a challenger is predicted to beat
    it by SWITCH_MARGIN at that level."""
    grid = cm.level_grid()
    lat0 = [cm.latency(hw, v, units, grid[0]) for v in versions]
    anchor = lat0.index(min(lat0))
    table = []
    for itf in grid:
        lats = [cm.latency(hw, v, units, itf) for v in versions]
        best = lats.index(min(lats))
        table.append(best if lats[anchor] > SWITCH_MARGIN * lats[best]
                     else anchor)
    return table


@dataclasses.dataclass
class VersionSet:
    layer_name: str
    versions: list[cm.CodeVersion]
    level_table: list[int]          # interference level idx -> version idx
    dominant_count: int             # |Pareto frontier| before selection
    candidate_count: int            # raw enumeration size

    def select(self, itf: cm.Interference) -> cm.CodeVersion:
        return self.versions[self.level_table[cm.level_to_idx(itf.level)]]

    def solo_version(self) -> cm.CodeVersion:
        return self.versions[self.level_table[0]]


def compile_layer(layer: cm.GemmLayer, hw: cm.HardwareSpec,
                  qos_budget_s: float | None = None, *,
                  v_max: int = V_MAX, retention: float = RETENTION,
                  ref_units: int | None = None) -> VersionSet:
    """Single-pass multi-version compilation for one layer."""
    ref_units = ref_units or max(hw.n_units // 4, 1)
    impls = ss.enumerate_versions(layer, hw)
    candidate_count = len(impls)

    # step 2: QoS filter (solo latency on all units must fit the budget)
    if qos_budget_s is not None:
        feasible = [v for v in impls
                    if cm.latency(hw, v, hw.n_units, cm.Interference())
                    <= qos_budget_s]
        if feasible:
            impls = feasible

    # step 3: Pareto frontier
    dom = extract_dominant(impls)
    dom.sort(key=lambda v: v.tile_bytes)

    # step 4a: pick V along the frontier — force-include the zero- and
    # max-interference winners (impl-1 / impl-4 of Fig. 6), fill uniformly
    if len(dom) <= v_max:
        picked = list(dom)
    else:
        grid = cm.level_grid()
        best0 = min(dom, key=lambda v: cm.latency(hw, v, ref_units, grid[0]))
        best9 = min(dom, key=lambda v: cm.latency(hw, v, ref_units, grid[-1]))
        forced = {dom.index(best0), dom.index(best9)}
        idxs = sorted(forced | {round(i * (len(dom) - 1) / (v_max - 1))
                                for i in range(v_max)})
        while len(idxs) > v_max:
            # drop a non-forced index, innermost first
            for i in idxs[1:-1]:
                if i not in forced:
                    idxs.remove(i)
                    break
            else:
                idxs = idxs[:v_max]
        picked = [dom[i] for i in idxs]

    # step 4b: redundancy pruning against the full-set latency envelope
    full_env = _best_latency_table(hw, picked, ref_units)
    keep = list(picked)
    changed = True
    while changed and len(keep) > 1:
        changed = False
        for v in sorted(keep, key=lambda v: -v.tile_bytes):
            trial = [w for w in keep if w is not v]
            env = _best_latency_table(hw, trial, ref_units)
            if all(e <= f / retention for e, f in zip(env, full_env)):
                keep = trial
                changed = True
                break

    keep.sort(key=lambda v: v.tile_bytes)
    return VersionSet(
        layer_name=layer.name,
        versions=keep,
        level_table=_select_by_level(hw, keep, ref_units),
        dominant_count=len(dom),
        candidate_count=candidate_count,
    )


def compile_model(layers: list[cm.GemmLayer], hw: cm.HardwareSpec,
                  model_qos_s: float | None = None,
                  **kw) -> list[VersionSet]:
    """Compile every layer; per-layer QoS slice proportional to its FLOPs
    (the paper's minimal-FLOPS-to-meet-model-latency rule)."""
    total = sum(l.flops for l in layers) or 1.0
    out = []
    for l in layers:
        budget = (model_qos_s * l.flops / total
                  if model_qos_s is not None else None)
        out.append(compile_layer(l, hw, budget, **kw))
    return out
