"""Unit (core/chip) pool with conflict accounting."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class UnitPool:
    total: int
    free: int = -1
    conflicts: int = 0
    requests: int = 0
    peak_used: int = 0

    def __post_init__(self):
        if self.free < 0:
            self.free = self.total

    @property
    def used(self) -> int:
        return self.total - self.free

    def try_alloc(self, n: int) -> int:
        """Allocate up to n units; returns the number granted (0 if none
        free).  A grant below the request counts as a scheduling conflict."""
        self.requests += 1
        grant = min(n, self.free)
        if grant < n:
            self.conflicts += 1
        self.free -= grant
        self.peak_used = max(self.peak_used, self.used)
        return grant

    def release(self, n: int) -> None:
        self.free += n
        assert self.free <= self.total, "double free"

    @property
    def conflict_rate(self) -> float:
        return self.conflicts / self.requests if self.requests else 0.0
