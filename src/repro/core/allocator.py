"""Unit (core/chip) pool with conflict accounting.

One :class:`UnitPool` is the single shared hardware resource both online
paths partition: the simulator allocates per layer-block chunk, the
co-location cluster (``repro.serving.cluster``) re-partitions it across
engines at every scheduling quantum.  Invariant: ``free + used == total``
at all times, so the sum of outstanding grants can never exceed
``hw.n_units``."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class UnitPool:
    total: int
    free: int = -1
    conflicts: int = 0
    requests: int = 0
    peak_used: int = 0

    def __post_init__(self):
        if self.free < 0:
            self.free = self.total

    @property
    def used(self) -> int:
        return self.total - self.free

    def try_alloc(self, n: int) -> int:
        """Allocate up to n units; returns the number granted (0 if none
        free).  A grant below the request counts as a scheduling conflict."""
        return self.try_alloc_range(n, n)

    def try_alloc_range(self, lo: int, hi: int) -> int:
        """Work-conserving range allocation: grant up to ``hi`` units from
        whatever is free; a grant below the QoS-minimum ``lo`` counts as a
        scheduling conflict (the caller may still run degraded on the
        partial grant, or stall on a zero grant)."""
        self.requests += 1
        grant = min(hi, self.free)
        if grant < lo:
            self.conflicts += 1
        self.free -= grant
        self.peak_used = max(self.peak_used, self.used)
        return grant

    def release(self, n: int) -> None:
        self.free += n
        assert self.free <= self.total, "double free"

    @property
    def conflict_rate(self) -> float:
        return self.conflicts / self.requests if self.requests else 0.0
