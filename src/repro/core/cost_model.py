"""Analytical latency model under interference (the compiler's oracle).

The model reproduces the paper's empirical findings structurally:

  * versions lie on a parallelism <-> locality trade-off (Fig. 9a):
    bigger tiles cut shared-memory traffic (reuse) but limit the useful
    parallel width and claim more cache/VMEM;
  * a version tuned for zero interference collapses under contention
    (Fig. 6, up to ~7x): its working set spills out of the *shared* cache
    and the bandwidth it leans on is being eaten by co-runners;
  * interference attacks the *shared* resources only: LLC capacity + DRAM
    bandwidth on the CPU platform, HBM bandwidth (chip co-residents) + ICI
    links (adjacent sub-meshes) on the TPU platform.  Compute is private
    and unaffected.

Latency = amdahl(compute) joined with contended memory and collective terms
(max = perfect overlap; a configurable overlap factor interpolates).
All numbers are plain Python floats — the scheduler/simulator calls this
thousands of times per simulated second.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    n_units: int                 # cores (CPU) or chips (TPU sub-mesh pool)
    unit: str                    # "core" | "chip"
    flops_per_unit: float        # peak FLOP/s per unit
    private_cache_bytes: float   # L2 per core / VMEM per chip (tile must fit)
    shared_cache_bytes: float    # LLC (CPU); 0 => no shared cache (TPU)
    shared_bw: float             # contended bandwidth: DRAM+LLC bw (CPU),
                                 # HBM bw per chip (TPU co-residency)
    link_bw: float               # ICI per link (TPU); 0 => no comm term
    realloc_overhead_s: float    # thread respawn (CPU) / resharding (TPU)
    serial_overhead_s: float     # per-layer launch overhead
    amdahl_serial: float         # non-parallel fraction of layer work
    overlap: float = 1.0         # 1 = compute/mem/comm fully overlapped
    # compute-efficiency curve: eff(tile) = base + slope*log2(tile/64KiB),
    # clipped to [eff_min, eff_max] (calibrated against the paper's absolute
    # CPU latencies / realistic TPU MXU utilizations)
    eff_base: float = 0.28
    eff_slope: float = 0.06
    eff_min: float = 0.18
    eff_max: float = 0.55

    @property
    def cache_shared(self) -> bool:
        return self.shared_cache_bytes > 0


# Paper platform: AMD Threadripper 3990X, 64 cores, AVX2 @2.9GHz,
# 256 MB LLC, quad-channel DDR4-3200 (~100 GB/s), ~1 TB/s aggregate LLC bw.
CPU_3990X = HardwareSpec(
    name="amd-3990x", n_units=64, unit="core",
    flops_per_unit=46.4e9,           # 16 fp32 FLOP/cycle * 2.9 GHz
    private_cache_bytes=512e3,       # L2 per core
    shared_cache_bytes=256e6,
    shared_bw=100e9,                 # quad-channel DDR4-3200 DRAM
    link_bw=0.0,
    realloc_overhead_s=220e-6,       # measured thread-spawn cost (Fig. 5b)
    serial_overhead_s=8e-6,
    amdahl_serial=0.005,
    # calibrated against Fig. 1a (~300 QPS solo on 64 cores => ~3.3 ms
    # ResNet-50) and Fig. 3b (18.5 ms at the layer-wise allocation)
    eff_base=0.50, eff_slope=0.06, eff_min=0.35, eff_max=0.82,
)

# Target platform: one TPU v5e pod as the shared multi-tenant resource.
TPU_V5E_POD = HardwareSpec(
    name="tpu-v5e-pod", n_units=256, unit="chip",
    flops_per_unit=197e12,           # bf16
    private_cache_bytes=96e6,        # ~VMEM usable budget (structural)
    shared_cache_bytes=0.0,          # VMEM is private: no spill term
    shared_bw=819e9,                 # HBM per chip (shared by co-residents)
    link_bw=50e9,                    # per ICI link
    realloc_overhead_s=1e-3,         # program swap + weight re-layout
    serial_overhead_s=5e-6,
    amdahl_serial=0.01,
    eff_base=0.45, eff_slope=0.05, eff_min=0.30, eff_max=0.85,
)


@dataclasses.dataclass(frozen=True)
class Interference:
    """Co-runner demand sums on each shared resource (fair-share model).

    Each field is the SUM of co-runner demands as a fraction of capacity
    (may exceed 1 under oversubscription).  Contention is fair-share:
    bandwidth time scales by (1 + bw); cache capacity is split
    proportionally to claims, so a victim whose claim c satisfies
    c + cache > 1 overflows by (c + cache - 1)."""
    cache: float = 0.0    # co-runner shared-cache claims (CPU only)
    bw: float = 0.0       # co-runner memory-bandwidth demand
    ici: float = 0.0      # co-runner link demand (TPU only)

    # level <-> resource mapping: level 1.0 == heavy co-location (LLC 2x
    # oversubscribed, bandwidth demand 1.5x capacity) — the top of the
    # paper's 10-level scale.
    CACHE_AT_1 = 2.0
    BW_AT_1 = 1.5
    ICI_AT_1 = 1.5

    @property
    def level(self) -> float:
        """Scalar pressure (what the paper's 10 discrete levels index)."""
        return min(max(self.cache / self.CACHE_AT_1,
                       self.bw / self.BW_AT_1,
                       self.ici / self.ICI_AT_1), 1.0)

    @staticmethod
    def from_level(x: float) -> "Interference":
        x = min(max(x, 0.0), 1.0)
        return Interference(cache=Interference.CACHE_AT_1 * x,
                            bw=Interference.BW_AT_1 * x,
                            ici=Interference.ICI_AT_1 * x)


NUM_LEVELS = 10  # paper: ten interference levels


def grid_point(i: int) -> float:
    """Level of grid index i.  Quadratically denser near 1.0 — on both
    platforms the version crossovers concentrate at high pressure (shared
    caches/bandwidth only saturate once co-runners claim most of them)."""
    return (i / (NUM_LEVELS - 1)) ** 0.5


def level_to_idx(level: float) -> int:
    x = min(max(level, 0.0), 1.0)
    return min(int(round(x * x * (NUM_LEVELS - 1))), NUM_LEVELS - 1)


def level_grid() -> list[Interference]:
    return [Interference.from_level(grid_point(i))
            for i in range(NUM_LEVELS)]


@dataclasses.dataclass(frozen=True)
class GemmLayer:
    """One schedulable layer, reduced to an effective GEMM.

    convs are im2col'd (m=OH*OW*B, k=Cin*KH*KW, n=Cout); transformer blocks
    aggregate their GEMMs into (m=tokens, k=d_model, n=flops/(2*m*k)).
    ``weight_bytes`` rides along for weight-traffic accounting.
    """
    name: str
    m: int
    k: int
    n: int
    itemsize: int = 4
    weight_bytes: float = 0.0
    comm_bytes_per_unit: float = 0.0   # TP collective bytes when sharded

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k * self.n

    @property
    def io_bytes(self) -> float:
        return self.itemsize * (self.m * self.k + self.m * self.n) + \
            (self.weight_bytes or self.itemsize * self.k * self.n)


@dataclasses.dataclass(frozen=True)
class CodeVersion:
    """One compiled implementation of a layer (a point in the trade-off
    space).  ``parallelism`` = independent tiles x unroll (the paper's
    parallelism metric); ``tile_bytes`` = blocking size (locality metric)."""
    layer_name: str
    bm: int
    bk: int
    bn: int
    unroll: int
    parallelism: int
    tile_bytes: int
    flops: float
    mem_bytes: float            # shared-level traffic given this tiling
    naive_bytes: float          # traffic bound when reuse collapses
    resident_bytes: float = 0.0  # LLC-resident operand panels (pollution)
    comm_bytes_per_unit: float = 0.0
    mxu_efficiency: float = 1.0

    @property
    def locality(self) -> float:
        return float(self.tile_bytes)

    def key(self) -> tuple:
        return (self.bm, self.bk, self.bn, self.unroll)


def _shared_traffic(hw: HardwareSpec, v: CodeVersion, units_eff: int,
                    itf: Interference) -> float:
    """Shared-memory traffic under pressure.  Versions whose tiles spill
    past the private cache (L2 per core) lean on the *shared* LLC for
    reuse — the paper's "interference-vulnerable high-locality" case:
    under cache oversubscription their fair share shrinks below their
    claim and reuse collapses toward the naive-traffic bound.  Small-tile
    versions are private-cache-resident and immune to the capacity term
    (but not to bandwidth contention)."""
    traffic = v.mem_bytes
    if hw.cache_shared and v.tile_bytes > hw.private_cache_bytes:
        claim_frac = (v.tile_bytes * units_eff + v.resident_bytes) \
            / hw.shared_cache_bytes
        total = claim_frac + itf.cache
        if total > 1.0:
            overflow = min(total - 1.0, 1.0)
            traffic = v.mem_bytes + overflow * (v.naive_bytes - v.mem_bytes)
    return traffic


def latency(hw: HardwareSpec, v: CodeVersion, units: int,
            itf: Interference) -> float:
    """Predicted latency (seconds) of one layer version on ``units`` units
    under interference ``itf``."""
    units = max(1, min(units, hw.n_units))
    units_eff = max(1, min(units, v.parallelism))

    # compute: private, unaffected by interference; Amdahl + launch overhead
    peak = hw.flops_per_unit * v.mxu_efficiency
    t_par = v.flops * (1.0 - hw.amdahl_serial) / (units_eff * peak)
    t_ser = v.flops * hw.amdahl_serial / peak
    t_comp = t_par + t_ser

    traffic = _shared_traffic(hw, v, units_eff, itf)
    # fair-share bandwidth: co-runner demand stretches memory time linearly
    bw_scale = 1.0 if hw.cache_shared else float(units)  # HBM scales w/ chips
    t_mem = traffic * (1.0 + itf.bw) / (hw.shared_bw * bw_scale)

    # collective term (TPU): TP all-reduce bytes over contended ICI links
    t_comm = 0.0
    if hw.link_bw > 0 and units > 1 and v.comm_bytes_per_unit > 0:
        comm = v.comm_bytes_per_unit * 2.0 * (units - 1) / units
        t_comm = comm * (1.0 + itf.ici) / hw.link_bw

    bound = max(t_comp, t_mem, t_comm)
    serial_sum = t_comp + t_mem + t_comm
    t = bound * hw.overlap + (1.0 - hw.overlap) * serial_sum
    return t + hw.serial_overhead_s


def units_required(hw: HardwareSpec, v: CodeVersion, budget_s: float,
                   itf: Interference) -> int:
    """Minimal units for latency(v, units) <= budget.

    If the budget is infeasible even on the whole machine (e.g. the layer
    is pinned on contended shared bandwidth, where extra units don't
    help), return the *knee* at this pressure — the smallest allocation
    within 5% of the best achievable — instead of demanding everything.
    Burning cores cannot buy back shared-resource time."""
    lo, hi = 1, hw.n_units
    best = latency(hw, v, hi, itf)
    target = budget_s if best <= budget_s else 1.05 * best
    while lo < hi:
        mid = (lo + hi) // 2
        if latency(hw, v, mid, itf) <= target:
            hi = mid
        else:
            lo = mid + 1
    return lo


def bw_demand(hw: HardwareSpec, v: CodeVersion, units: int,
              itf: Interference = Interference()) -> float:
    """Fraction of shared bandwidth this (version, units) consumes while
    running under conditions ``itf`` — the 'performance counter' the
    interference proxy reads.  Uses the *realized* traffic (a spilled
    chunk streams its collapsed-reuse bytes, not its blocked ideal), which
    is what closes the paper's contention feedback loop."""
    units_eff = max(1, min(units, v.parallelism))
    traffic = _shared_traffic(hw, v, units_eff, itf)
    t = latency(hw, v, units, itf)
    bw_scale = 1.0 if hw.cache_shared else float(max(units, 1))
    return min((traffic / t) / (hw.shared_bw * bw_scale), 1.0)


def cache_demand(hw: HardwareSpec, v: CodeVersion, units: int) -> float:
    """LLC occupancy a running chunk imposes on everyone else: its
    resident operand panels (all versions pollute with their streams) plus
    its active tiles when those live in the LLC."""
    if not hw.cache_shared:
        return 0.0
    units_eff = max(1, min(units, v.parallelism))
    claim = v.resident_bytes
    if v.tile_bytes > hw.private_cache_bytes:
        claim += v.tile_bytes * units_eff
    return min(claim / hw.shared_cache_bytes, 1.0)


def ici_demand(hw: HardwareSpec, v: CodeVersion, units: int,
               itf: Interference = Interference()) -> float:
    if hw.link_bw <= 0 or units <= 1 or v.comm_bytes_per_unit <= 0:
        return 0.0
    t = latency(hw, v, units, itf)
    comm = v.comm_bytes_per_unit * 2.0 * (units - 1) / units
    return min((comm / t) / hw.link_bw, 1.0)
