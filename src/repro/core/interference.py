"""Interference pressure accounting + the linear performance-counter proxy.

The *true* pressure a task experiences is the sum of the shared-resource
demands of its co-runners (cost_model.bw_demand / cache_demand /
ici_demand).  The paper instead reads hardware counters and maps them to a
pressure level with a linear model (L3 miss rate + L3 accesses explain >99%
of variance, Fig. 11).  We reproduce both sides:

  * ``pressure_on``      — ground truth from co-runner demand sums
                           (what the simulator charges latencies with);
  * ``CounterSample``    — the "performance counters" a running system
                           would read (synthesized from the same demands,
                           plus distractor counters for the PCA experiment);
  * ``LinearProxy``      — fit on (counters -> level) calibration pairs,
                           used by the *scheduler* at run time, so the
                           scheduler sees proxy error like the real system.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core.cost_model import HardwareSpec, Interference

SOON_FINISH_FRACTION = 0.10   # paper: ignore blocks with <10% latency left

# Online proxy re-fit (sliding-window recursive least squares): the proxy
# keeps tracking the counter->pressure mapping as traffic drifts away from
# the offline calibration distribution.
RLS_WINDOW = 128      # (counter, pressure) pairs kept for window refits
RLS_FORGET = 0.97     # exponential forgetting factor (per update)
DRIFT_WINDOW = 16     # residuals pooled for the drift detector
DRIFT_SPIKE = 3.0     # recent RMS > spike * calibration RMS => refit


@dataclasses.dataclass
class RunningDemand:
    """Resource demand of one running layer-block (computed at start)."""
    tenant: int
    bw: float
    cache: float
    ici: float
    start: float
    finish: float

    def soon_done(self, now: float) -> bool:
        span = max(self.finish - self.start, 1e-12)
        return (self.finish - now) / span < SOON_FINISH_FRACTION


def pressure_on(tenant: int, demands: list[RunningDemand], now: float,
                *, exclude_soon_done: bool = True) -> Interference:
    """Interference experienced by ``tenant``: sum of everyone else's
    demands (fair-share model; sums may exceed 1, capped for sanity)."""
    bw = cache = ici = 0.0
    for d in demands:
        if d.tenant == tenant:
            continue
        if exclude_soon_done and d.soon_done(now):
            continue
        bw += d.bw
        cache += d.cache
        ici += d.ici
    return Interference(cache=min(cache, 4.0), bw=min(bw, 4.0),
                        ici=min(ici, 4.0))


# --------------------------------------------------------------------------
# Synthesized performance counters + linear proxy (paper Fig. 11)
# --------------------------------------------------------------------------
COUNTER_NAMES = ("l3_miss_rate", "l3_accesses", "ipc", "flop_rate",
                 "branch_rate", "frontend_stalls")


@dataclasses.dataclass
class CounterSample:
    """One performance-counter read (what a PMU poll would return).

    ``values`` follows :data:`COUNTER_NAMES` order; only the first two
    (the L3 counters) carry the interference signal the proxy consumes.
    ``truth`` is the ground-truth pressure the counters were synthesized
    from — it exists for calibration and proxy-accuracy tests ONLY and
    must never feed a scheduling decision (the runtime's level decisions
    flow through :class:`LinearProxy`, like the real system's).

    ``source`` records which sensor produced the sample: ``"oracle"``
    (synthesized from co-runner demand sums — the simulator/test path)
    or ``"measured"`` (derived from per-quantum wall times by a
    :class:`~repro.core.counters.CounterBank`; ``truth`` is None there,
    because a real system has no oracle)."""
    values: np.ndarray
    t: float
    truth: Interference | None = None
    source: str = "oracle"


def read_counters(hw: HardwareSpec, victim: int,
                  demands: list[RunningDemand], now: float,
                  rng: np.random.Generator, *,
                  exclude_soon_done: bool = True,
                  source: str = "oracle",
                  bank=None) -> CounterSample:
    """Poll the performance counters as seen by ``victim``.

    ``source="oracle"`` (default — the simulator/test path, and exactly
    the pre-measurement behavior): the true co-runner pressure decides
    what the counters *would read*; the proxy then maps the noisy counter
    values back to a pressure estimate, so the scheduler experiences
    proxy error exactly like the deployed system.  ``victim=-1`` matches
    no running demand, i.e. the caller observes the full co-runner
    pressure (an engine asking "what hits me right now").

    ``source="measured"``: the sample comes from ``bank`` (a
    :class:`~repro.core.counters.CounterBank` fed by the engine's
    per-quantum wall times) — no oracle is consulted and ``truth`` is
    None.  A cold bank (no usable observations yet) falls back to the
    oracle synthesizer for this poll; the returned sample is labelled
    ``"oracle"`` so callers can count how often the fallback fired."""
    if source not in ("oracle", "measured"):
        raise ValueError(f"counter source {source!r} not in "
                         "('oracle', 'measured')")
    if source == "measured":
        if bank is None:
            raise ValueError("source='measured' needs a CounterBank")
        sample = bank.sample(hw, now)
        if sample is not None:
            return sample
    truth = pressure_on(victim, demands, now,
                        exclude_soon_done=exclude_soon_done)
    values = synthesize_counters(hw, truth, rng)
    return CounterSample(values=values, t=now, truth=truth)


def synthesize_counters(hw: HardwareSpec, itf: Interference,
                        rng: np.random.Generator | None,
                        noise_scale: float = 1.0) -> np.ndarray:
    """What the perf counters would read under pressure ``itf``.

    L3-related counters respond to the shared-resource pressure (that is the
    paper's PCA finding); IPC responds inversely; the rest are distractors
    with small variance.  ``noise_scale=0.0`` gives the deterministic
    response curve (the CounterBank uses it to express a *measured*
    pressure in counter units — the transport format the proxy consumes —
    without injecting synthetic sensor noise); ``rng`` may then be None."""
    c = min(itf.cache / Interference.CACHE_AT_1, 1.0)
    b = min(itf.bw / Interference.BW_AT_1, 1.0)
    if noise_scale == 0.0 or rng is None:
        eps = np.zeros(6)
    else:
        eps = noise_scale * np.array([rng.normal(0, 0.015),
                                      rng.normal(0, 0.02),
                                      rng.normal(0, 0.05),
                                      rng.normal(0, 0.02),
                                      rng.normal(0, 0.005),
                                      rng.normal(0, 0.01)])
    miss = 0.08 + 0.85 * c + eps[0]
    acc = 0.20 + 0.75 * b + eps[1]
    ipc = 2.2 - 1.1 * max(c, b) + eps[2]
    flop = 0.6 + eps[3]
    branch = 0.05 + eps[4]
    stalls = 0.1 + 0.05 * itf.bw + eps[5]
    return np.array([miss, acc, ipc, flop, branch, stalls])


class LinearProxy:
    """Per-resource linear model on the two L3 counters (paper's proxy,
    vectorized per shared resource):

        cache_pressure ~= Wc . [miss, acc] + bc
        bw_pressure    ~= Wb . [miss, acc] + bb

    ``predict`` returns the scalar level (for reporting / Fig. 11b);
    ``predict_interference`` the per-resource pressures the scheduler
    consumes.

    Online re-fit: :meth:`rls_update` feeds one (counter sample, realized
    pressure) pair through a forgetting-factor recursive-least-squares
    step, so the proxy tracks traffic drift away from the offline
    calibration distribution.  A drift detector watches the residual
    stream: when the recent residual RMS spikes past ``DRIFT_SPIKE`` x
    the calibration-time RMS, the proxy is batch-refit on its sliding
    window (``refit_count`` counts these; ``rms_error`` reports the
    current window residual RMS — both surfaced in
    ``ServingMetrics.proxy_rms_error``/``refit_count``)."""

    def __init__(self):
        self.w = np.zeros((2, 2))
        self.b = np.zeros(2)
        self.r2 = float("nan")
        # online (RLS) state, lazily seeded from (w, b) on first update
        self._theta: np.ndarray | None = None     # (3, 2) stacked [W; b]
        self._P: np.ndarray | None = None         # (3, 3) inverse covariance
        self._win: collections.deque = collections.deque(maxlen=RLS_WINDOW)
        self._residuals: collections.deque = collections.deque(
            maxlen=RLS_WINDOW)
        self.base_rms = float("nan")   # calibration-time residual RMS
        self.refit_count = 0           # drift-triggered window refits
        self.rls_updates = 0           # online pairs consumed

    def fit(self, counters: np.ndarray,
            pressures: np.ndarray) -> "LinearProxy":
        """counters (n,2); pressures (n,2) = (cache, bw) demand sums."""
        x = np.column_stack([counters[:, 0], counters[:, 1],
                             np.ones(len(counters))])
        sol, *_ = np.linalg.lstsq(x, pressures, rcond=None)
        self.w, self.b = sol[:2].T, sol[2]
        pred = x @ sol
        ss_res = float(np.sum((pressures - pred) ** 2))
        ss_tot = float(np.sum((pressures - pressures.mean(0)) ** 2)) or 1.0
        self.r2 = 1.0 - ss_res / ss_tot
        resid = np.linalg.norm(pressures - pred, axis=1)
        self.base_rms = float(np.sqrt(np.mean(resid ** 2)))
        self._theta = None             # re-seed RLS from the fresh solution
        self._P = None
        self._win.clear()
        self._residuals.clear()
        return self

    # -- online re-fit -----------------------------------------------------
    @property
    def rms_error(self) -> float:
        """Residual RMS over the sliding window (nan before any update)."""
        if not self._residuals:
            return float("nan")
        r = np.asarray(self._residuals)
        return float(np.sqrt(np.mean(r ** 2)))

    @staticmethod
    def _target(pressure) -> np.ndarray:
        if isinstance(pressure, Interference):
            return np.array([pressure.cache, pressure.bw], dtype=float)
        return np.asarray(pressure, dtype=float)[:2]

    def rls_update(self, counters: np.ndarray, pressure) -> float:
        """One sliding-window RLS step on a (counters, realized pressure)
        pair.  ``pressure`` is an :class:`Interference` or a (cache, bw)
        array — the sample's oracle truth offline, the CounterBank's
        measured pressure online.  Returns the pre-update residual norm
        (the surprise this pair carried)."""
        x = np.array([float(counters[0]), float(counters[1]), 1.0])
        y = self._target(pressure)
        if self._theta is None:
            self._theta = np.vstack([self.w.T, self.b])
            self._P = np.eye(3) * 100.0
        resid = y - self._theta.T @ x
        px = self._P @ x
        denom = RLS_FORGET + float(x @ px)
        self._theta = self._theta + np.outer(px / denom, resid)
        self._P = (self._P - np.outer(px, px) / denom) / RLS_FORGET
        self.w, self.b = self._theta[:2].T, self._theta[2]
        self._win.append((x, y))
        err = float(np.linalg.norm(resid))
        self._residuals.append(err)
        self.rls_updates += 1
        # drift detection: a sustained residual spike means the counter->
        # pressure mapping moved faster than the forgetting factor tracks
        if len(self._residuals) >= DRIFT_WINDOW:
            recent = np.asarray(self._residuals)[-DRIFT_WINDOW:]
            recent_rms = float(np.sqrt(np.mean(recent ** 2)))
            floor = max(self.base_rms, 1e-3) if np.isfinite(self.base_rms) \
                else 1e-3
            if recent_rms > DRIFT_SPIKE * floor:
                self.refit_window()
        return err

    def refit_window(self) -> None:
        """Batch least-squares over the sliding window (the drift
        response): jump the model to the new regime instead of waiting
        for the forgetting factor to wash the old one out."""
        if len(self._win) < 4:
            return
        xs = np.array([x for x, _ in self._win])
        ys = np.array([y for _, y in self._win])
        sol, *_ = np.linalg.lstsq(xs, ys, rcond=None)
        self.w, self.b = sol[:2].T, sol[2]
        self._theta = sol
        self._P = np.eye(3) * 100.0
        self.refit_count += 1
        # the post-refit residuals define the new normal: both the live
        # window and the drift floor reset, so one regime change triggers
        # one refit, not one per subsequent sample
        resid = np.linalg.norm(ys - xs @ sol, axis=1)
        self._residuals.clear()
        self._residuals.extend(float(r) for r in resid[-DRIFT_WINDOW:])
        self.base_rms = max(float(np.sqrt(np.mean(resid ** 2))), 1e-3)

    def predict_interference(self, counters: np.ndarray) -> Interference:
        c2 = np.asarray(counters[:2], dtype=float)
        cache, bw = self.w @ c2 + self.b
        return Interference(
            cache=float(np.clip(cache, 0.0, Interference.CACHE_AT_1)),
            bw=float(np.clip(bw, 0.0, Interference.BW_AT_1)))

    def predict(self, counters: np.ndarray) -> float:
        return self.predict_interference(counters).level


def calibrate_proxy(hw: HardwareSpec, n: int = 512,
                    seed: int = 0) -> tuple[LinearProxy, np.ndarray,
                                            np.ndarray]:
    """Offline calibration pass: sweep *independent* cache/bw pressure
    mixes (co-runner mixes in production are not perfectly correlated),
    record counters, fit the linear proxy on the realized level."""
    rng = np.random.default_rng(seed)
    pts = []
    for i in range(n):
        if i % 2 == 0:        # correlated sweep (anchors the extremes)
            pts.append(Interference.from_level(rng.uniform()))
        else:                 # independent mixes (production co-runners)
            pts.append(Interference(
                cache=Interference.CACHE_AT_1 * rng.uniform(),
                bw=Interference.BW_AT_1 * rng.uniform(),
                ici=Interference.ICI_AT_1 * rng.uniform()))
    levels = np.array([p.level for p in pts])
    pressures = np.array([(p.cache, p.bw) for p in pts])
    counters = np.stack([synthesize_counters(hw, p, rng) for p in pts])
    proxy = LinearProxy().fit(counters[:, :2], pressures)
    return proxy, counters, levels


def pca_variance(counters: np.ndarray) -> np.ndarray:
    """Fraction of variance per principal component (Fig. 11a).

    Raw covariance (no per-counter standardization): the paper's finding
    is that the L3-driven counters carry nearly all the *actual* variance;
    standardizing would inflate the distractor counters' noise floor to
    parity and bury that signal."""
    x = counters - counters.mean(axis=0)
    _, s, _ = np.linalg.svd(x, full_matrices=False)
    var = s ** 2
    return var / var.sum()
