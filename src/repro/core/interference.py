"""Interference pressure accounting + the linear performance-counter proxy.

The *true* pressure a task experiences is the sum of the shared-resource
demands of its co-runners (cost_model.bw_demand / cache_demand /
ici_demand).  The paper instead reads hardware counters and maps them to a
pressure level with a linear model (L3 miss rate + L3 accesses explain >99%
of variance, Fig. 11).  We reproduce both sides:

  * ``pressure_on``      — ground truth from co-runner demand sums
                           (what the simulator charges latencies with);
  * ``CounterSample``    — the "performance counters" a running system
                           would read (synthesized from the same demands,
                           plus distractor counters for the PCA experiment);
  * ``LinearProxy``      — fit on (counters -> level) calibration pairs,
                           used by the *scheduler* at run time, so the
                           scheduler sees proxy error like the real system.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost_model import HardwareSpec, Interference

SOON_FINISH_FRACTION = 0.10   # paper: ignore blocks with <10% latency left


@dataclasses.dataclass
class RunningDemand:
    """Resource demand of one running layer-block (computed at start)."""
    tenant: int
    bw: float
    cache: float
    ici: float
    start: float
    finish: float

    def soon_done(self, now: float) -> bool:
        span = max(self.finish - self.start, 1e-12)
        return (self.finish - now) / span < SOON_FINISH_FRACTION


def pressure_on(tenant: int, demands: list[RunningDemand], now: float,
                *, exclude_soon_done: bool = True) -> Interference:
    """Interference experienced by ``tenant``: sum of everyone else's
    demands (fair-share model; sums may exceed 1, capped for sanity)."""
    bw = cache = ici = 0.0
    for d in demands:
        if d.tenant == tenant:
            continue
        if exclude_soon_done and d.soon_done(now):
            continue
        bw += d.bw
        cache += d.cache
        ici += d.ici
    return Interference(cache=min(cache, 4.0), bw=min(bw, 4.0),
                        ici=min(ici, 4.0))


# --------------------------------------------------------------------------
# Synthesized performance counters + linear proxy (paper Fig. 11)
# --------------------------------------------------------------------------
COUNTER_NAMES = ("l3_miss_rate", "l3_accesses", "ipc", "flop_rate",
                 "branch_rate", "frontend_stalls")


@dataclasses.dataclass
class CounterSample:
    """One performance-counter read (what a PMU poll would return).

    ``values`` follows :data:`COUNTER_NAMES` order; only the first two
    (the L3 counters) carry the interference signal the proxy consumes.
    ``truth`` is the ground-truth pressure the counters were synthesized
    from — it exists for calibration and proxy-accuracy tests ONLY and
    must never feed a scheduling decision (the runtime's level decisions
    flow through :class:`LinearProxy`, like the real system's)."""
    values: np.ndarray
    t: float
    truth: Interference | None = None


def read_counters(hw: HardwareSpec, victim: int,
                  demands: list[RunningDemand], now: float,
                  rng: np.random.Generator, *,
                  exclude_soon_done: bool = True) -> CounterSample:
    """Poll the (synthesized) performance counters as seen by ``victim``.

    This is the online runtime's sensor: the true co-runner pressure is
    only used to decide what the counters *would read* — the proxy then
    maps the noisy counter values back to a pressure estimate, so the
    scheduler experiences proxy error exactly like the deployed system.
    ``victim=-1`` matches no running demand, i.e. the caller observes the
    full co-runner pressure (an engine asking "what hits me right now")."""
    truth = pressure_on(victim, demands, now,
                        exclude_soon_done=exclude_soon_done)
    values = synthesize_counters(hw, truth, rng)
    return CounterSample(values=values, t=now, truth=truth)


def synthesize_counters(hw: HardwareSpec, itf: Interference,
                        rng: np.random.Generator) -> np.ndarray:
    """What the perf counters would read under pressure ``itf``.

    L3-related counters respond to the shared-resource pressure (that is the
    paper's PCA finding); IPC responds inversely; the rest are distractors
    with small variance."""
    c = min(itf.cache / Interference.CACHE_AT_1, 1.0)
    b = min(itf.bw / Interference.BW_AT_1, 1.0)
    miss = 0.08 + 0.85 * c + rng.normal(0, 0.015)
    acc = 0.20 + 0.75 * b + rng.normal(0, 0.02)
    ipc = 2.2 - 1.1 * max(c, b) + rng.normal(0, 0.05)
    flop = 0.6 + rng.normal(0, 0.02)
    branch = 0.05 + rng.normal(0, 0.005)
    stalls = 0.1 + 0.05 * itf.bw + rng.normal(0, 0.01)
    return np.array([miss, acc, ipc, flop, branch, stalls])


class LinearProxy:
    """Per-resource linear model on the two L3 counters (paper's proxy,
    vectorized per shared resource):

        cache_pressure ~= Wc . [miss, acc] + bc
        bw_pressure    ~= Wb . [miss, acc] + bb

    ``predict`` returns the scalar level (for reporting / Fig. 11b);
    ``predict_interference`` the per-resource pressures the scheduler
    consumes."""

    def __init__(self):
        self.w = np.zeros((2, 2))
        self.b = np.zeros(2)
        self.r2 = float("nan")

    def fit(self, counters: np.ndarray,
            pressures: np.ndarray) -> "LinearProxy":
        """counters (n,2); pressures (n,2) = (cache, bw) demand sums."""
        x = np.column_stack([counters[:, 0], counters[:, 1],
                             np.ones(len(counters))])
        sol, *_ = np.linalg.lstsq(x, pressures, rcond=None)
        self.w, self.b = sol[:2].T, sol[2]
        pred = x @ sol
        ss_res = float(np.sum((pressures - pred) ** 2))
        ss_tot = float(np.sum((pressures - pressures.mean(0)) ** 2)) or 1.0
        self.r2 = 1.0 - ss_res / ss_tot
        return self

    def predict_interference(self, counters: np.ndarray) -> Interference:
        c2 = np.asarray(counters[:2], dtype=float)
        cache, bw = self.w @ c2 + self.b
        return Interference(
            cache=float(np.clip(cache, 0.0, Interference.CACHE_AT_1)),
            bw=float(np.clip(bw, 0.0, Interference.BW_AT_1)))

    def predict(self, counters: np.ndarray) -> float:
        return self.predict_interference(counters).level


def calibrate_proxy(hw: HardwareSpec, n: int = 512,
                    seed: int = 0) -> tuple[LinearProxy, np.ndarray,
                                            np.ndarray]:
    """Offline calibration pass: sweep *independent* cache/bw pressure
    mixes (co-runner mixes in production are not perfectly correlated),
    record counters, fit the linear proxy on the realized level."""
    rng = np.random.default_rng(seed)
    pts = []
    for i in range(n):
        if i % 2 == 0:        # correlated sweep (anchors the extremes)
            pts.append(Interference.from_level(rng.uniform()))
        else:                 # independent mixes (production co-runners)
            pts.append(Interference(
                cache=Interference.CACHE_AT_1 * rng.uniform(),
                bw=Interference.BW_AT_1 * rng.uniform(),
                ici=Interference.ICI_AT_1 * rng.uniform()))
    levels = np.array([p.level for p in pts])
    pressures = np.array([(p.cache, p.bw) for p in pts])
    counters = np.stack([synthesize_counters(hw, p, rng) for p in pts])
    proxy = LinearProxy().fit(counters[:, :2], pressures)
    return proxy, counters, levels


def pca_variance(counters: np.ndarray) -> np.ndarray:
    """Fraction of variance per principal component (Fig. 11a).

    Raw covariance (no per-counter standardization): the paper's finding
    is that the L3-driven counters carry nearly all the *actual* variance;
    standardizing would inflate the distractor counters' noise floor to
    parity and bury that signal."""
    x = counters - counters.mean(axis=0)
    _, s, _ = np.linalg.svd(x, full_matrices=False)
    var = s ** 2
    return var / var.sum()
