"""Alg. 3 — the VELTAIR runtime scheduler, plus the policy interface the
discrete-event simulator drives.

A policy is asked, at admission and at every block boundary, to plan the
next chunk of a task: which layers, how many units, which code versions.
VELTAIR's policy implements the paper's loop:

    i     <- proxy-predicted system interference (excl. soon-to-finish)
    thres <- (C_total - sum of active models' Avg_C) distributed
             proportionally to each model's Avg_C
    pivot <- Finding1stPivot(remaining layers, impls_i, thres)
    execute layers[begin:pivot] with the interference-matched versions

Ablations: VELTAIR-AS (adaptive scheduling only: blocks formed dynamically
but solo-tuned code), VELTAIR-AC (adaptive compilation only: layer-wise
scheduling with interference-matched versions), VELTAIR-FULL (both).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import cost_model as cm
from repro.core import layer_block as lb
from repro.core.interference import (CounterSample, LinearProxy,
                                     RunningDemand, calibrate_proxy,
                                     read_counters)


@dataclasses.dataclass
class ChunkPlan:
    end_layer: int
    units: int                    # desired (work-conserving) allocation
    versions: list[cm.CodeVersion]
    budget_s: float
    units_min: int = 0            # QoS-required minimum (conflict threshold)
    exclusive: bool = False       # temporal policies: need the whole machine
    allow_partial: bool = True    # start with fewer units + realloc overhead

    def __post_init__(self):
        if self.units_min <= 0:
            self.units_min = self.units


@dataclasses.dataclass
class TaskState:
    tid: int
    tenant: str
    plan: lb.ModelPlan
    arrival: float
    priority: float = 0.0
    next_layer: int = 0
    tier: str | None = None          # SLO tier label (core.qos.TIER_ORDER)
    deadline: float | None = None    # absolute tier-scaled deadline; None
                                     # falls back to arrival + qos_s

    @property
    def done(self) -> bool:
        return self.next_layer >= self.plan.n_layers

    def remaining_budget(self, now: float) -> float:
        if self.deadline is not None:
            return self.deadline - now
        return (self.arrival + self.plan.qos_s) - now


class Policy:
    """Scheduling-policy interface, driven from three call sites:

    * the discrete-event simulator calls :meth:`plan_chunk` at admission
      and at every block boundary (oracle co-runner demands in hand);
    * the online runtimes (``repro.serving.runtime`` /
      ``repro.serving.cluster``) poll performance counters and call
      :meth:`level_from_counters` / :meth:`plan_chunk_at` — the policy
      never sees ground-truth pressure there, only the counter sample;
    * both ask :meth:`order_pending` for the dispatch order.
    """
    name = "base"
    strict_fcfs = False

    def __init__(self, hw: cm.HardwareSpec):
        self.hw = hw

    def plan_chunk(self, task: TaskState, active: list[TaskState],
                   demands: list[RunningDemand], now: float,
                   free_units: int) -> Optional[ChunkPlan]:
        raise NotImplementedError

    def plan_chunk_at(self, task: TaskState, active: list[TaskState],
                      itf: cm.Interference, now: float,
                      free_units: int) -> Optional[ChunkPlan]:
        """Plan the next chunk given an already-estimated pressure ``itf``
        (the online cluster path: counters -> proxy -> itf -> plan).
        Static baselines ignore pressure, so the default just forwards to
        :meth:`plan_chunk` with no demand list."""
        return self.plan_chunk(task, active, [], now, free_units)

    def order_pending(self, pending: list[TaskState],
                      now: float) -> list[TaskState]:
        """Dispatch order for waiting tasks (default: FCFS by arrival)."""
        return sorted(pending, key=lambda t: t.arrival)

    def order_by_slack(self, pending: list[TaskState],
                       now: float) -> list[TaskState]:
        """Earliest-deadline order (least remaining budget first) — the
        SLO-tiered runtimes use this when tasks carry tier deadlines;
        ties break FCFS so untiered tasks degrade to arrival order."""
        return sorted(pending,
                      key=lambda t: (t.remaining_budget(now), t.arrival,
                                     t.tid))

    def interference_from_counters(self,
                                   sample: CounterSample) -> cm.Interference:
        """Pressure estimate from one performance-counter read.  Static
        baselines do not sense pressure at all."""
        return cm.Interference()

    def level_from_counters(self, sample: CounterSample) -> float:
        """Interference level the serving engine should compile for, given
        a live counter sample (the online runtimes call this every
        scheduling quantum).  Baselines without adaptive compilation pin
        the solo-tuned code version (level 0)."""
        return 0.0

    def online_level(self, demands: list[RunningDemand],
                     now: float) -> float:
        """Interference level from oracle demand sums (legacy hook, kept
        for direct policy probing in tests; the runtimes now synthesize a
        :class:`~repro.core.interference.CounterSample` and use
        :meth:`level_from_counters` instead).  Static baselines never
        leave the solo-tuned code version."""
        return 0.0

    def observe_counters(self, sample: CounterSample,
                         target: cm.Interference) -> None:
        """Feed one (counter sample, realized pressure) pair back into the
        policy's pressure estimator — the online re-fit hook the runtimes
        call when serving with measured counters.  ``target`` is the
        pressure the sample is later known to correspond to (oracle truth
        where available, else the counter bank's slowdown-derived
        estimate).  Baselines have no estimator; no-op."""
        return None

    @property
    def proxy_rms_error(self) -> float:
        """Sliding-window RMS residual of the policy's pressure proxy
        (NaN for policies without one / before any observation)."""
        return float("nan")

    @property
    def proxy_refits(self) -> int:
        """Drift-triggered proxy refits so far (0 without an estimator)."""
        return 0


class VeltairPolicy(Policy):
    """The full adaptive compiler+scheduler (paper Alg. 3).

    Reproduces: VELTAIR-FULL, plus its two ablations — VELTAIR-AS
    (``adaptive_compile=False``: dynamic layer-blocks, solo-tuned code)
    and VELTAIR-AC (``adaptive_schedule=False``: layer-wise dispatch,
    interference-matched code versions).

    Decision inputs: the proxy-predicted interference (performance
    counters through :class:`~repro.core.interference.LinearProxy` —
    never the oracle pressure), the dynamic threshold from the active
    tenants' ``Avg_C``, and the per-model multi-version tables."""

    def __init__(self, hw: cm.HardwareSpec, *, adaptive_schedule: bool = True,
                 adaptive_compile: bool = True, proxy: LinearProxy | None = None,
                 seed: int = 0):
        super().__init__(hw)
        self.adaptive_schedule = adaptive_schedule
        self.adaptive_compile = adaptive_compile
        self.proxy = proxy or calibrate_proxy(hw)[0]
        self.rng = np.random.default_rng(seed)
        self.name = ("veltair-full" if adaptive_schedule and adaptive_compile
                     else "veltair-as" if adaptive_schedule
                     else "veltair-ac")

    def _predicted_itf(self, task: TaskState, demands: list[RunningDemand],
                       now: float) -> cm.Interference:
        return self._predict_pressure(task.tid, demands, now)

    def _predict_pressure(self, tid: int, demands: list[RunningDemand],
                          now: float) -> cm.Interference:
        sample = read_counters(self.hw, tid, demands, now, self.rng)
        if self.hw.cache_shared:
            return self.interference_from_counters(sample)
        # TPU platform simulator path: the link-pressure registers are not
        # part of the synthesized counter vector, so the simulator charges
        # the realized ICI pressure directly (the bw/cache estimate still
        # goes through the proxy like the CPU platform)
        pred = self.interference_from_counters(sample)
        return cm.Interference(cache=0.0, bw=pred.bw,
                               ici=min(sample.truth.ici, 4.0))

    def interference_from_counters(self, sample):
        pred = self.proxy.predict_interference(
            np.asarray(sample.values)[:2])
        if self.hw.cache_shared:
            return pred
        # no shared cache on the TPU platform: only the bandwidth estimate
        # is meaningful (the proxy reads bandwidth-pressure registers of
        # the same linear structure)
        return cm.Interference(cache=0.0, bw=pred.bw, ici=0.0)

    def level_from_counters(self, sample):
        if not self.adaptive_compile:
            return 0.0        # VELTAIR-AS serves the solo-tuned version
        return self.interference_from_counters(sample).level

    def online_level(self, demands, now):
        if not self.adaptive_compile:
            return 0.0        # VELTAIR-AS serves the solo-tuned version
        # tid=-1 matches no running demand, so the proxy sees the full
        # co-runner pressure — the engine itself is the "victim"
        return self._predict_pressure(-1, demands, now).level

    def observe_counters(self, sample, target):
        self.proxy.rls_update(np.asarray(sample.values)[:2], target)

    @property
    def proxy_rms_error(self):
        return self.proxy.rms_error

    @property
    def proxy_refits(self):
        return self.proxy.refit_count

    def _threshold(self, task: TaskState, active: list[TaskState]) -> float:
        total_avg = sum(t.plan.avg_units for t in active) or 1
        idle = self.hw.n_units - total_avg
        if idle <= 0:
            return 0.0
        return idle * task.plan.avg_units / total_avg

    def plan_chunk(self, task, active, demands, now, free_units):
        itf = self._predicted_itf(task, demands, now)
        return self.plan_chunk_at(task, active, itf, now, free_units)

    def plan_chunk_at(self, task, active, itf, now, free_units):
        if self.adaptive_schedule:
            thres = self._threshold(task, active)
            blk = lb.next_block(task.plan, task.next_layer, self.hw, itf,
                                thres, adaptive_compile=self.adaptive_compile)
            # work-conserving: up to the knee while idle, but never past
            # Avg_C + thres (the dynamic cap that keeps conflicts low)
            cap = max(int(task.plan.avg_units + thres), blk.units)
            knee = lb.versions_knee(self.hw, blk.versions)
            desired = min(max(blk.units, knee), cap, self.hw.n_units)
            return ChunkPlan(end_layer=blk.end, units=desired,
                             versions=blk.versions, budget_s=blk.budget_s,
                             units_min=blk.units)
        # layer-wise scheduling with adaptive compilation (VELTAIR-AC)
        i = task.next_layer
        vs = task.plan.version_sets[i]
        v = vs.select(itf) if self.adaptive_compile else vs.solo_version()
        budget = task.plan.budgets[i]
        units_min = min(cm.units_required(self.hw, v, budget,
                                          cm.Interference()),
                        self.hw.n_units)
        desired = max(units_min, lb.versions_knee(self.hw, [v]))
        return ChunkPlan(end_layer=i + 1, units=desired, versions=[v],
                         budget_s=budget, units_min=units_min)


class ModelWisePolicy(Policy):
    """FCFS whole-model scheduling (the paper's prior-work baseline,
    Fig. 3/12 "model-wise": one static allocation for the entire model,
    provisioned at the low-load operating point).

    Decision inputs: the plan's precomputed ``fcfs_units`` only — no
    pressure sensing, no mid-model re-planning (``strict_fcfs`` keeps the
    queue in arrival order and a query either gets its full allocation or
    waits)."""
    name = "model-wise"
    strict_fcfs = True

    def plan_chunk(self, task, active, demands, now, free_units):
        plan = task.plan
        versions = [vs.solo_version() for vs in plan.version_sets]
        return ChunkPlan(end_layer=plan.n_layers, units=plan.fcfs_units,
                         versions=versions, budget_s=plan.qos_s,
                         allow_partial=False)


class LayerWisePolicy(Policy):
    """Planaria-style spatial layer-wise scheduling (arXiv 2003.04696)
    ported to the unit pool: per-layer minimal allocation,
    start-small-and-grow on conflict (the paper charges the measured
    ~220us respawn overhead for that).

    Decision inputs: the plan's per-layer solo unit requirements — code
    versions stay solo-tuned and pressure is never sensed; the
    fine-grained re-planning itself is the (overhead-prone) mechanism."""
    name = "layer-wise"

    def plan_chunk(self, task, active, demands, now, free_units):
        i = task.next_layer
        v = task.plan.version_sets[i].solo_version()
        units_min = min(task.plan.layer_units[i], self.hw.n_units)
        desired = max(units_min, lb.versions_knee(self.hw, [v]))
        return ChunkPlan(end_layer=i + 1, units=desired, versions=[v],
                         budget_s=task.plan.budgets[i], units_min=units_min)


class FixedBlockPolicy(Policy):
    """Static layer-blocks of a fixed size (paper Fig. 3's block-6 /
    block-11 design points): the middle granularities between model-wise
    and layer-wise that motivate *adaptive* block formation.

    Decision inputs: the constant ``block_size`` and the solo-tuned
    version table — block boundaries never react to load or pressure."""

    def __init__(self, hw, block_size: int):
        super().__init__(hw)
        self.block_size = block_size
        self.name = f"block-{block_size}"

    def plan_chunk(self, task, active, demands, now, free_units):
        plan = task.plan
        i = task.next_layer
        end = min(i + self.block_size, plan.n_layers)
        versions = [vs.solo_version() for vs in plan.version_sets[i:end]]
        budget = sum(plan.budgets[i:end])
        units_min = lb._block_units(self.hw, versions, budget,
                                    cm.Interference(), self.hw.n_units)
        desired = max(units_min, lb.versions_knee(self.hw, versions))
        return ChunkPlan(end_layer=end, units=desired, versions=versions,
                         budget_s=budget, units_min=units_min)


class PremaPolicy(Policy):
    """PREMA-style temporal multiplexing (arXiv 1909.04548 / the paper's
    time-sharing baseline): one task at a time on the whole machine,
    preemptible at layer boundaries.

    Decision inputs: waiting time and QoS slack only (the slack-aware
    token in :meth:`order_pending`); spatial pressure never exists since
    execution is exclusive."""
    name = "prema"

    def plan_chunk(self, task, active, demands, now, free_units):
        i = task.next_layer
        v = task.plan.version_sets[i].solo_version()
        return ChunkPlan(end_layer=i + 1, units=self.hw.n_units,
                         versions=[v], budget_s=task.plan.budgets[i],
                         exclusive=True, allow_partial=False)

    def order_pending(self, pending, now):
        def token(t: TaskState):
            waited = now - t.arrival
            return -(waited / max(t.plan.qos_s, 1e-6))
        return sorted(pending, key=token)
