"""GEMM-reduced per-layer profiles of the assigned LM architectures.

This is the bridge between the model substrate and the VELTAIR core: a
transformer block's GEMMs are aggregated into one effective GEMM (exact
FLOPs, representative dims), giving the scheduler/compiler the per-layer
workload profile it needs for the TPU-pod serving scenario.

For MoE layers only the *active* expert FLOPs count (top-k + shared +
dense-residual); comm_bytes_per_unit carries the TP all-reduce payload
(activation bytes) for the cost model's collective term.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.cost_model import GemmLayer

IT = 2  # bf16 on TPU


def _layer_flops(cfg: ModelConfig, tokens: int, kv_len: int,
                 kind: str) -> tuple[float, float]:
    """-> (flops, weight_bytes) for one layer of ``kind``."""
    m = cfg.d_model
    fl = 0.0
    wb = 0.0
    if kind in ("dense", "moe_arctic", "attn_local"):
        h, k, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        qkvo = m * h * d * 2 + m * k * d * 2 * 2 + h * d * m * 2
        fl += tokens * qkvo
        wb += (m * h * d + 2 * m * k * d + h * d * m) * IT
        att_len = kv_len
        if kind == "attn_local" and cfg.rglru:
            att_len = min(kv_len, cfg.rglru.window_size)
        elif cfg.sliding_window:
            att_len = min(kv_len, cfg.sliding_window)
        fl += 2 * 2 * tokens * att_len * h * d       # qk^T + pv
    if kind in ("dense", "attn_local"):
        n_mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
        fl += tokens * n_mats * m * cfg.d_ff * 2
        wb += n_mats * m * cfg.d_ff * IT
    if kind == "moe_arctic":
        moe = cfg.moe
        fl += tokens * 3 * m * cfg.d_ff * 2                       # dense res
        fl += tokens * moe.top_k * 3 * m * moe.expert_d_ff * 2    # routed
        fl += tokens * m * moe.num_experts * 2                    # router
        wb += 3 * m * cfg.d_ff * IT
        wb += moe.num_experts * 3 * m * moe.expert_d_ff * IT
    if kind == "moe_ds":
        mla, moe = cfg.mla, cfg.moe
        h = cfg.num_heads
        dn, dr, dv = (mla.qk_nope_head_dim, mla.qk_rope_head_dim,
                      mla.v_head_dim)
        r = mla.kv_lora_rank
        proj = m * h * (dn + dr) + m * (r + dr) + r * h * (dn + dv) \
            + h * dv * m
        fl += tokens * proj * 2
        wb += proj * IT
        fl += 2 * 2 * tokens * kv_len * h * (dn + dr + dv) / 2
        fl += tokens * moe.top_k * 3 * m * moe.expert_d_ff * 2
        fl += tokens * 3 * m * moe.shared_d_ff * 2
        fl += tokens * m * moe.num_experts * 2
        wb += moe.num_experts * 3 * m * moe.expert_d_ff * IT
    if kind == "ssm":
        s = cfg.ssm
        d_in = 2 * s.d_inner + 2 * s.num_groups * s.state_dim + s.num_heads
        fl += tokens * m * d_in * 2 + tokens * s.d_inner * m * 2
        wb += (m * d_in + s.d_inner * m) * IT
        # SSD: intra-chunk (Q per token) + state updates
        q = s.chunk_size
        fl += tokens * s.num_heads * (2 * q * s.state_dim
                                      + 2 * q * s.head_dim
                                      + 4 * s.head_dim * s.state_dim)
    if kind == "rec":
        rg = cfg.rglru
        w = rg.lru_width
        bw_ = w // max(cfg.num_heads, 1)
        fl += tokens * (2 * m * w * 2 + 2 * w * bw_ * 2 + w * m * 2 + 8 * w)
        wb += (2 * m * w + w * m + 2 * w * bw_ * max(cfg.num_heads, 1)) * IT
        n_mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
        fl += tokens * n_mats * m * cfg.d_ff * 2
        wb += n_mats * m * cfg.d_ff * IT
    return fl, wb


def lm_layer_kinds(cfg: ModelConfig) -> list[str]:
    from repro.models.model import make_plan
    plan = make_plan(cfg)
    kinds = list(plan.prologue)
    for _ in range(plan.n_groups):
        kinds.extend(plan.scan_kinds)
    kinds.extend(plan.epilogue)
    # normalize block kinds to profile kinds
    return ["dense" if k == "ds_dense0" else k for k in kinds]


def lm_layers(cfg: ModelConfig, shape: ShapeConfig) -> list[GemmLayer]:
    """One effective GEMM per transformer block for (arch x shape)."""
    if shape.mode == "decode":
        tokens = shape.global_batch
        kv_len = shape.seq_len
    else:
        tokens = shape.global_batch * shape.seq_len
        kv_len = shape.seq_len
    out = []
    for i, kind in enumerate(lm_layer_kinds(cfg)):
        fl, wb = _layer_flops(cfg, tokens, kv_len, kind)
        k_eff = cfg.d_model
        m_eff = max(tokens, 1)
        n_eff = max(int(fl / (2 * m_eff * k_eff)), 1)
        # TP all-reduce payload: one activation tensor per sharded matmul
        comm = 2 * tokens * cfg.d_model * IT
        out.append(GemmLayer(name=f"{cfg.name}.L{i}.{kind}", m=m_eff,
                             k=k_eff, n=n_eff, itemsize=IT, weight_bytes=wb,
                             comm_bytes_per_unit=float(comm)))
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Active-parameter step FLOPs (MODEL_FLOPS for the roofline ratio)."""
    return sum(l.flops for l in lm_layers(cfg, shape)) + \
        2 * (shape.global_batch if shape.mode == "decode"
             else shape.global_batch * shape.seq_len) \
        * cfg.d_model * cfg.vocab_size
