"""Alg. 2 — dynamic-threshold layer-block formation.

A layer whose unit requirement exceeds ``Avg_C + thres`` is a *splitting
pivot*: it starts a new block.  Each block's unit budget is then
recalculated so the whole block meets the sum of its layers' QoS slices
using at most ``Avg_C + thres`` units — high-demand layers borrow time from
their cheap neighbours instead of spiking the allocation (paper Fig. 10a).

Consumers: the simulator executes blocks in analytic time; the
co-location cluster (``repro.serving.cluster``) reuses the same
formation on the real path — a block's layer count becomes an engine's
dispatch quantum (decode steps between scheduling interventions) and its
unit requirement the engine's pool share, so scheduling granularity
adapts to pressure exactly as Alg. 2 prescribes.
"""
from __future__ import annotations

import dataclasses

from repro.core import cost_model as cm
from repro.core.multiversion import VersionSet


@dataclasses.dataclass
class LayerBlock:
    start: int                      # layer index range [start, end)
    end: int
    units: int                      # recalculated block requirement
    budget_s: float                 # sum of member QoS slices
    versions: list[cm.CodeVersion]  # chosen implementation per member layer

    @property
    def n_layers(self) -> int:
        return self.end - self.start

    def latency(self, hw: cm.HardwareSpec, units: int,
                itf: cm.Interference) -> float:
        return sum(cm.latency(hw, v, units, itf) for v in self.versions)


@dataclasses.dataclass
class ModelPlan:
    """Per-model compile-time artifacts the scheduler works from."""
    name: str
    layers: list[cm.GemmLayer]
    version_sets: list[VersionSet]
    qos_s: float
    budgets: list[float]            # per-layer QoS slice
    avg_units: int                  # Avg_C: mean per-layer requirement (§4.2)
    layer_units: list[int]          # layer-wise minimal units (solo, itf=0)
    fcfs_units: int = 0             # model-wise FCFS provisioning (knee)

    @property
    def n_layers(self) -> int:
        return len(self.layers)


def make_model_plan(name: str, layers: list[cm.GemmLayer],
                    version_sets: list[VersionSet], qos_s: float,
                    hw: cm.HardwareSpec) -> ModelPlan:
    itf0 = cm.Interference()
    # Per-layer QoS slice proportional to the layer's *full-machine* latency
    # (the paper's minimal-FLOPS rule, made overhead-aware so tiny layers
    # keep launch-cost slack).  Layers that scale poorly demand many units
    # to hit their slice — these are Fig. 4b's conflict-prone spikes.
    ref = [cm.latency(hw, vs.solo_version(), hw.n_units, itf0)
           for vs in version_sets]
    total = sum(ref) or 1.0
    budgets = [qos_s * r / total for r in ref]
    layer_units = [
        cm.units_required(hw, vs.solo_version(), b, itf0)
        for vs, b in zip(version_sets, budgets)]
    # Avg_C (§4.2): the model's averaged per-layer core requirement
    avg_units = max(1, round(sum(min(u, hw.n_units) for u in layer_units)
                             / len(layer_units)))
    # Model-wise FCFS provisions for comfortable-margin latency (~60% of
    # QoS, the paper's Fig. 3b low-load operating point) — the
    # over-allocation VELTAIR's finer granularity recovers (Fig. 4b's
    # black line vs the red shadowed area).
    fcfs_units = _model_granularity_units(hw, version_sets, 0.6 * qos_s,
                                          itf0)
    return ModelPlan(name=name, layers=layers, version_sets=version_sets,
                     qos_s=qos_s, budgets=budgets, avg_units=avg_units,
                     layer_units=layer_units, fcfs_units=fcfs_units)


def _knee_units(hw: cm.HardwareSpec, version_sets: list[VersionSet],
                itf: cm.Interference, slack: float = 1.10) -> int:
    """Smallest uniform allocation within ``slack`` of full-machine latency."""
    full = sum(cm.latency(hw, vs.solo_version(), hw.n_units, itf)
               for vs in version_sets)
    lo, hi = 1, hw.n_units
    while lo < hi:
        mid = (lo + hi) // 2
        lat = sum(cm.latency(hw, vs.solo_version(), mid, itf)
                  for vs in version_sets)
        if lat <= slack * full:
            hi = mid
        else:
            lo = mid + 1
    return lo


def _model_granularity_units(hw: cm.HardwareSpec,
                             version_sets: list[VersionSet], qos_s: float,
                             itf: cm.Interference) -> int:
    """Minimal uniform unit count for the whole model to meet QoS."""
    lo, hi = 1, hw.n_units
    def total(u):
        return sum(cm.latency(hw, vs.solo_version(), u, itf)
                   for vs in version_sets)
    if total(hi) > qos_s:
        return hw.n_units
    while lo < hi:
        mid = (lo + hi) // 2
        if total(mid) <= qos_s:
            hi = mid
        else:
            lo = mid + 1
    return lo


_REQ_CACHE: dict = {}


def layer_requirements(plan: ModelPlan, hw: cm.HardwareSpec,
                       itf: cm.Interference, *,
                       adaptive_compile: bool = True) -> tuple[
                           list[int], list[cm.CodeVersion]]:
    """Per-layer unit requirement + chosen version at pressure ``itf``.

    Memoized on the quantized pressure level (10-level grid, like the
    paper's discrete interference levels) — the simulator calls this at
    every block boundary."""
    key = (plan.name, hw.name, round(itf.cache, 1), round(itf.bw, 1),
           round(itf.ici, 1), adaptive_compile)
    hit = _REQ_CACHE.get(key)
    if hit is not None:
        return hit
    units, versions = [], []
    for vs, budget in zip(plan.version_sets, plan.budgets):
        v = vs.select(itf) if adaptive_compile else vs.solo_version()
        versions.append(v)
        units.append(cm.units_required(hw, v, budget, itf))
    _REQ_CACHE[key] = (units, versions)
    return units, versions


def finding_first_pivot(reqs: list[int], avg_c: int, thres: float,
                        start: int) -> int:
    """Alg. 2 Finding1stPivot: first layer (after start) whose requirement
    exceeds Avg_C + thres; returns len(reqs) if none."""
    for i in range(start + 1, len(reqs)):
        if reqs[i] >= avg_c + thres:
            return i
    return len(reqs)


_KNEE_CACHE: dict = {}


def versions_knee(hw: cm.HardwareSpec, versions: list[cm.CodeVersion],
                  slack: float = 1.30) -> int:
    """Smallest unit count within ``slack`` of the full-machine latency for
    this version list — the work-conserving 'grab cores while idle' target
    (paper: 'each layer can use as many cores as possible when load is
    low')."""
    key = (hw.name, tuple(v.layer_name for v in versions),
           tuple(v.key() for v in versions))
    hit = _KNEE_CACHE.get(key)
    if hit is not None:
        return hit
    itf = cm.Interference()
    full = sum(cm.latency(hw, v, hw.n_units, itf) for v in versions)
    lo, hi = 1, hw.n_units
    while lo < hi:
        mid = (lo + hi) // 2
        if sum(cm.latency(hw, v, mid, itf) for v in versions) \
                <= slack * full:
            hi = mid
        else:
            lo = mid + 1
    _KNEE_CACHE[key] = lo
    return lo


def _block_units(hw: cm.HardwareSpec, versions: list[cm.CodeVersion],
                 budget_s: float, itf: cm.Interference, cap: int) -> int:
    """Minimal units for the block to meet its summed budget (<= cap)."""
    lo, hi = 1, max(cap, 1)
    def lat(u):
        return sum(cm.latency(hw, v, u, itf) for v in versions)
    if lat(hi) > budget_s:
        return hi                     # best effort at the cap
    while lo < hi:
        mid = (lo + hi) // 2
        if lat(mid) <= budget_s:
            hi = mid
        else:
            lo = mid + 1
    return lo


def next_block(plan: ModelPlan, begin: int, hw: cm.HardwareSpec,
               itf: cm.Interference, thres: float, *,
               adaptive_compile: bool = True) -> LayerBlock:
    """Form the next layer-block starting at ``begin`` (runtime use).

    Versions are selected at the full predicted pressure (that is what the
    multi-version tables are for); unit *requirements* are provisioned at
    zero pressure — under fair-share contention extra units cannot buy
    back shared-bandwidth time, so inflating allocations with the
    interference level only raises the conflict rate (validated in
    EXPERIMENTS.md §Simulator-calibration)."""
    reqs, versions = layer_requirements(plan, hw, itf,
                                        adaptive_compile=adaptive_compile)
    itf0 = cm.Interference()
    reqs0, _ = layer_requirements(plan, hw, itf0,
                                  adaptive_compile=adaptive_compile)
    end = finding_first_pivot(reqs0, plan.avg_units, thres, begin)
    end = max(end, begin + 1)
    budget = sum(plan.budgets[begin:end])
    cap = min(int(plan.avg_units + thres) if thres < hw.n_units
              else hw.n_units, hw.n_units)
    cap = max(cap, 1)
    vset = versions[begin:end]
    units = _block_units(hw, vset, budget, itf0, cap)
    return LayerBlock(start=begin, end=end, units=units, budget_s=budget,
                      versions=vset)


def form_blocks(plan: ModelPlan, hw: cm.HardwareSpec, itf: cm.Interference,
                thres: float, *, adaptive_compile: bool = True,
                ) -> list[LayerBlock]:
    """Full static partition (offline analysis / Fig. 10 reproduction)."""
    out = []
    begin = 0
    while begin < plan.n_layers:
        blk = next_block(plan, begin, hw, itf, thres,
                         adaptive_compile=adaptive_compile)
        out.append(blk)
        begin = blk.end
    return out
