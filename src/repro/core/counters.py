"""Measured performance counters from per-quantum wall times.

This is the measurement half of the adaptive-compilation loop.  The
oracle path (``read_counters(source="oracle")``) synthesizes counter
values from co-runner demand sums — fine for the simulator and for
calibration, but it means the serving loop's sensor is simulated.  A
:class:`CounterBank` closes that gap: the engine timestamps every
dispatch quantum (``ServingEngine.begin_quantum``/``finish_quantum``
and the finishing prefill chunk — the points with a real device->host
sync, so the wall time covers device work, not dispatch overhead), and
the bank turns those (quantum kind, K-bucket, tile config, co-runner
count) observations into a per-engine *slowdown* estimate:

    slowdown = median(recent wall) / baseline wall        (per shape key)

where the baseline is the fastest wall ever observed for that exact
(kind, bucket, tiles) key — the uncontended floor.  The fair-share cost
model says memory time under co-runner bandwidth demand ``bw`` scales by
``(1 + bw)``, and level 1.0 pins ``bw = Interference.BW_AT_1`` — so the
measured slowdown maps back to a pressure level as

    level = clip((slowdown - 1) / BW_AT_1, 0, 1)

and :meth:`sample` re-expresses that pressure in counter units (the
deterministic response curve of ``synthesize_counters``), producing a
:class:`~repro.core.interference.CounterSample` with ``source=
"measured"`` and no oracle ``truth`` — the same transport format the
calibrated :class:`~repro.core.interference.LinearProxy` consumes, so
the whole decision path downstream of the sensor is unchanged.

Attribution contract (see ``tests/test_measured_counters.py``): the
engine stamps ``t0`` *after* version-cache lookup/AOT-compile and
*after* the scheduler's ``set_interference_level`` switch, and skips the
observation entirely when a jax trace happened inside the timed span —
host-side scheduling and compile time are already charged by the
runtimes (``compile_time_s``) and must never double-count into the
measured counters.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core.cost_model import HardwareSpec, Interference
from repro.core.interference import CounterSample, synthesize_counters

# fair-share model: at level 1.0 the co-runner bandwidth demand is
# BW_AT_1, stretching memory time by (1 + BW_AT_1) — i.e. a slowdown of
# (1 + BW_AT_1) over the uncontended floor maps to level 1.0
SLOWDOWN_AT_1 = Interference.BW_AT_1

WINDOW = 64            # recent observations pooled per slowdown estimate
MIN_KEY_OBS = 2        # observations before a key's floor is trusted


@dataclasses.dataclass(frozen=True)
class QuantumObservation:
    """One timed dispatch quantum (as recorded by the engine)."""
    kind: str            # "decode" | "prefill" | "spec" (speculative
                         # verify quanta get their own wall-time floors:
                         # one (B, d+1) forward is a different shape
                         # class than K sequential decode steps, and
                         # pooling them would corrupt both baselines)
    bucket: int          # K-bucket (decode) / padded chunk size (prefill)
    tiles: tuple         # version-cache tiles key of the active version
    wall_s: float        # measured wall time, sync to sync
    tokens: int = 0      # tokens the quantum produced/consumed
    co_runners: int = 0  # co-resident active slots elsewhere (observability)
    t: float = 0.0       # virtual time of the observation

    @property
    def key(self) -> tuple:
        return (self.kind, self.bucket, self.tiles)


class CounterBank:
    """Sliding-window slowdown estimator over timed dispatch quanta.

    One bank per engine.  ``observe`` is called by the engine at every
    synced quantum boundary; ``sample`` is called by the runtime's
    counter poll (through ``read_counters(source="measured")``) and
    returns None while the bank is cold — no key has both a trusted
    baseline and a recent observation — letting the caller fall back to
    the oracle synthesizer for that poll."""

    def __init__(self, *, window: int = WINDOW,
                 min_key_obs: int = MIN_KEY_OBS):
        self.window = int(window)
        self.min_key_obs = int(min_key_obs)
        self._floor: dict[tuple, float] = {}    # key -> fastest wall seen
        self._count: dict[tuple, int] = {}      # key -> observations
        self._recent: collections.deque = collections.deque(
            maxlen=self.window)
        self.observations = 0

    # ------------------------------------------------------------------
    def observe(self, kind: str, bucket: int, tiles: tuple,
                wall_s: float, *, tokens: int = 0, co_runners: int = 0,
                t: float = 0.0) -> QuantumObservation:
        """Record one timed quantum; returns the stored observation."""
        obs = QuantumObservation(kind=str(kind), bucket=int(bucket),
                                 tiles=tuple(tiles), wall_s=float(wall_s),
                                 tokens=int(tokens),
                                 co_runners=int(co_runners), t=float(t))
        if obs.wall_s <= 0.0:
            return obs
        key = obs.key
        floor = self._floor.get(key)
        if floor is None or obs.wall_s < floor:
            self._floor[key] = obs.wall_s
        self._count[key] = self._count.get(key, 0) + 1
        self._recent.append(obs)
        self.observations += 1
        return obs

    @property
    def last(self) -> QuantumObservation | None:
        return self._recent[-1] if self._recent else None

    # ------------------------------------------------------------------
    def slowdown(self) -> float | None:
        """Median wall/floor ratio over the recent window (>= 1.0 by
        construction), or None while cold.  The median is the robustness
        knob: one GC pause or noisy-neighbor spike must not swing the
        level decision."""
        ratios = [obs.wall_s / self._floor[obs.key]
                  for obs in self._recent
                  if self._count.get(obs.key, 0) >= self.min_key_obs]
        if not ratios:
            return None
        return float(np.median(ratios))

    def level(self) -> float | None:
        s = self.slowdown()
        if s is None:
            return None
        return float(np.clip((s - 1.0) / SLOWDOWN_AT_1, 0.0, 1.0))

    def pressure(self) -> Interference | None:
        """Measured pressure estimate (the RLS target online)."""
        lvl = self.level()
        if lvl is None:
            return None
        return Interference.from_level(lvl)

    def sample(self, hw: HardwareSpec, now: float) -> CounterSample | None:
        """The measured counter poll: re-express the bank's pressure in
        counter units (deterministic response curve — the measurement
        noise is already in the wall times) as a ``source="measured"``
        sample, or None while cold."""
        itf = self.pressure()
        if itf is None:
            return None
        values = synthesize_counters(hw, itf, None, noise_scale=0.0)
        return CounterSample(values=values, t=now, truth=None,
                             source="measured")
