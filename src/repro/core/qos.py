"""QoS targets, satisfaction tracking and serving metrics."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class QueryRecord:
    tenant: str
    arrival: float
    finish: float
    qos_s: float
    units_time: float = 0.0          # integral of units x time (efficiency)
    ttft_s: float | None = None      # time to first token (metered prefill;
                                     # None where the path cannot observe it)

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def satisfied(self) -> bool:
        return self.latency <= self.qos_s


@dataclasses.dataclass
class ServingMetrics:
    qps_offered: float
    qos_rate: float                 # fraction of queries meeting QoS
    avg_latency_s: float
    p99_latency_s: float
    conflict_rate: float
    avg_units: float                # mean units used by running queries
    unit_efficiency: float          # useful busy-time / allocated unit-time
    n_queries: int = 0              # completed queries behind these numbers
    avg_ttft_s: float = 0.0         # mean time-to-first-token over records
                                    # that observed one (0.0 otherwise)


def summarize(records: list[QueryRecord], qps_offered: float,
              conflict_rate: float, busy_unit_time: float,
              alloc_unit_time: float) -> ServingMetrics:
    if not records:
        return ServingMetrics(qps_offered, 0.0, float("inf"), float("inf"),
                              conflict_rate, 0.0, 0.0)
    lats = np.array([r.latency for r in records])
    sat = np.mean([r.satisfied for r in records])
    span = max(max(r.finish for r in records)
               - min(r.arrival for r in records), 1e-9)
    avg_units = alloc_unit_time / span
    eff = busy_unit_time / alloc_unit_time if alloc_unit_time > 0 else 0.0
    ttfts = [r.ttft_s for r in records if r.ttft_s is not None]
    return ServingMetrics(
        qps_offered=qps_offered,
        qos_rate=float(sat),
        avg_latency_s=float(lats.mean()),
        p99_latency_s=float(np.percentile(lats, 99)),
        conflict_rate=conflict_rate,
        avg_units=float(avg_units),
        unit_efficiency=float(eff),
        n_queries=len(records),
        avg_ttft_s=float(np.mean(ttfts)) if ttfts else 0.0,
    )


def compare_metrics(a: ServingMetrics,
                    b: ServingMetrics) -> dict[str, tuple[float, float]]:
    """Field-by-field (a, b) pairs — side-by-side comparison of the same
    workload replayed through the simulator and the real engine."""
    return {f.name: (getattr(a, f.name), getattr(b, f.name))
            for f in dataclasses.fields(ServingMetrics)}


def qps_at_qos(sweep: list[tuple[float, ServingMetrics]],
               target: float = 0.95) -> float:
    """Max offered QPS whose QoS satisfaction rate stays >= target
    (MLPerf-server style metric), linearly interpolated between grid
    points (rate -> 1.0 as qps -> 0)."""
    pts = sorted((q, m.qos_rate) for q, m in sweep)
    prev_q, prev_r = 0.0, 1.0
    best = 0.0
    for q, r in pts:
        if r >= target:
            best = q
            prev_q, prev_r = q, r
            continue
        if prev_r > target >= r and prev_r > r:
            best = max(best, prev_q + (q - prev_q)
                       * (prev_r - target) / (prev_r - r))
        prev_q, prev_r = q, r
    return best
