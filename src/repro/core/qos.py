"""QoS targets, SLO tiers, satisfaction tracking and serving metrics.

Tier model (paper §scheduling, PREMA-style latency tiers): every request
belongs to one of three SLO tiers.  A tier scales the tenant's base QoS
target into an absolute *deadline* (``arrival + deadline_scale *
qos_s``) and carves out a TTFT sub-deadline (``arrival + ttft_frac *
deadline_scale * qos_s``) for the first token.  Schedulers order
quanta by earliest deadline; the admission controller may shed work
from ``sheddable`` tiers whose deadline is already hopeless.

Untiered records (``deadline is None``) keep the legacy semantics:
satisfied iff ``latency <= qos_s``.  That keeps every pre-existing
workload's qos_rate bit-identical.
"""
from __future__ import annotations

import dataclasses

import numpy as np

TIER_ORDER = ("interactive", "standard", "batch")


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One SLO tier: how a tenant's base QoS target becomes a deadline."""
    name: str
    deadline_scale: float     # deadline = arrival + deadline_scale * qos_s
    ttft_frac: float          # TTFT deadline = arrival + ttft_frac * scale*qos
    sheddable: bool           # admission may reject when deadline is hopeless


DEFAULT_TIERS: dict[str, TierSpec] = {
    "interactive": TierSpec("interactive", 1.0, 0.4, sheddable=True),
    "standard": TierSpec("standard", 2.5, 0.6, sheddable=True),
    "batch": TierSpec("batch", 8.0, 1.0, sheddable=False),
}


def tier_spec(name: str | None,
              tiers: dict[str, TierSpec] | None = None) -> TierSpec:
    """Resolve a tier name (``None`` -> standard) to its spec."""
    table = tiers or DEFAULT_TIERS
    if name is None:
        return table["standard"]
    if name not in table:
        raise ValueError(f"unknown SLO tier {name!r}; "
                         f"expected one of {sorted(table)}")
    return table[name]


@dataclasses.dataclass
class QueryRecord:
    tenant: str
    arrival: float
    finish: float
    qos_s: float
    units_time: float = 0.0          # integral of units x time (efficiency)
    ttft_s: float | None = None      # time to first token (metered prefill;
                                     # None where the path cannot observe it)
    tier: str = "standard"           # SLO tier label (reporting only unless
                                     # deadline is set)
    deadline: float | None = None    # absolute deadline; None = legacy
                                     # qos_s-relative satisfaction

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def satisfied(self) -> bool:
        if self.deadline is not None:
            return self.finish <= self.deadline
        return self.latency <= self.qos_s


@dataclasses.dataclass
class TierMetrics:
    """Per-tier slice of the same record schema both runtimes emit."""
    n_queries: int
    qos_rate: float
    avg_latency_s: float
    p99_latency_s: float
    avg_ttft_s: float = 0.0


@dataclasses.dataclass
class ServingMetrics:
    qps_offered: float
    qos_rate: float                 # fraction of queries meeting QoS
    avg_latency_s: float
    p99_latency_s: float
    conflict_rate: float
    avg_units: float                # mean units used by running queries
    unit_efficiency: float          # useful busy-time / allocated unit-time
    n_queries: int = 0              # completed queries behind these numbers
    avg_ttft_s: float = 0.0         # mean time-to-first-token over records
                                    # that observed one (0.0 otherwise)
    qps_at_qos: float = 0.0         # queries served *under QoS* per second
                                    # over the serving span (headline)
    shed_queries: int = 0           # rejected by admission control (counted,
                                    # never silently dropped)
    deferred_queries: int = 0       # admissions delayed past arrival by the
                                    # admission controller
    peak_cache_tokens: int = 0      # max tokens live requests held resident
                                    # at once (KV-cache occupancy high-water)
    cache_utilization: float = 0.0  # peak valid tokens / resident capacity —
                                    # dense pins slots*max_len, paged pins
                                    # allocated pages (shared pages counted
                                    # once, so sharing can push this past 1)
    proxy_rms_error: float = float("nan")  # sliding-window RMS residual of
                                    # the policy's pressure proxy (NaN for
                                    # policies without one / oracle runs
                                    # that never feed it)
    refit_count: int = 0            # drift-triggered online proxy refits
    tokens_accepted: int = 0        # draft tokens accepted by speculative
                                    # verify quanta (0 on non-spec runs)
    draft_hit_rate: float = 0.0     # tokens_accepted / tokens_drafted —
                                    # the workload's speculation quality
    spec_rollbacks: int = 0         # spec quanta where >= 1 draft position
                                    # was rejected and rolled back
    per_tier: dict[str, TierMetrics] = dataclasses.field(default_factory=dict)


def _tier_slice(records: list[QueryRecord]) -> TierMetrics:
    lats = np.array([r.latency for r in records])
    ttfts = [r.ttft_s for r in records if r.ttft_s is not None]
    return TierMetrics(
        n_queries=len(records),
        qos_rate=float(np.mean([r.satisfied for r in records])),
        avg_latency_s=float(lats.mean()),
        p99_latency_s=float(np.percentile(lats, 99)),
        avg_ttft_s=float(np.mean(ttfts)) if ttfts else 0.0,
    )


def summarize(records: list[QueryRecord], qps_offered: float,
              conflict_rate: float, busy_unit_time: float,
              alloc_unit_time: float, *, shed: int = 0,
              deferred: int = 0, peak_cache_tokens: int = 0,
              cache_utilization: float = 0.0,
              proxy_rms_error: float = float("nan"),
              refit_count: int = 0, tokens_accepted: int = 0,
              draft_hit_rate: float = 0.0,
              spec_rollbacks: int = 0) -> ServingMetrics:
    """The one record->metrics reduction.  Both ``OnlineRuntime.serve``
    and ``ClusterRuntime.serve`` (per tenant and aggregate) funnel their
    tier-labelled ``QueryRecord``s through here, so per-tier
    qos_rate/TTFT/p99 report identically from either path."""
    if not records:
        return ServingMetrics(qps_offered, 0.0, float("inf"), float("inf"),
                              conflict_rate, 0.0, 0.0,
                              shed_queries=shed, deferred_queries=deferred,
                              peak_cache_tokens=peak_cache_tokens,
                              cache_utilization=cache_utilization,
                              proxy_rms_error=proxy_rms_error,
                              refit_count=refit_count,
                              tokens_accepted=tokens_accepted,
                              draft_hit_rate=draft_hit_rate,
                              spec_rollbacks=spec_rollbacks)
    lats = np.array([r.latency for r in records])
    sat = np.mean([r.satisfied for r in records])
    span = max(max(r.finish for r in records)
               - min(r.arrival for r in records), 1e-9)
    avg_units = alloc_unit_time / span
    eff = busy_unit_time / alloc_unit_time if alloc_unit_time > 0 else 0.0
    ttfts = [r.ttft_s for r in records if r.ttft_s is not None]
    n_sat = int(sum(r.satisfied for r in records))
    per_tier: dict[str, TierMetrics] = {}
    for tier in TIER_ORDER:
        rs = [r for r in records if r.tier == tier]
        if rs:
            per_tier[tier] = _tier_slice(rs)
    return ServingMetrics(
        qps_offered=qps_offered,
        qos_rate=float(sat),
        avg_latency_s=float(lats.mean()),
        p99_latency_s=float(np.percentile(lats, 99)),
        conflict_rate=conflict_rate,
        avg_units=float(avg_units),
        unit_efficiency=float(eff),
        n_queries=len(records),
        avg_ttft_s=float(np.mean(ttfts)) if ttfts else 0.0,
        qps_at_qos=n_sat / span,
        shed_queries=shed,
        deferred_queries=deferred,
        peak_cache_tokens=peak_cache_tokens,
        cache_utilization=cache_utilization,
        proxy_rms_error=proxy_rms_error,
        refit_count=refit_count,
        tokens_accepted=tokens_accepted,
        draft_hit_rate=draft_hit_rate,
        spec_rollbacks=spec_rollbacks,
        per_tier=per_tier,
    )


def compare_metrics(a: ServingMetrics,
                    b: ServingMetrics) -> dict[str, tuple[float, float]]:
    """Field-by-field (a, b) pairs — side-by-side comparison of the same
    workload replayed through the simulator and the real engine."""
    return {f.name: (getattr(a, f.name), getattr(b, f.name))
            for f in dataclasses.fields(ServingMetrics)
            if f.name != "per_tier"}


def qps_at_qos(sweep: list[tuple[float, ServingMetrics]],
               target: float = 0.95) -> float:
    """Max offered QPS whose QoS satisfaction rate stays >= target
    (MLPerf-server style metric), linearly interpolated between grid
    points (rate -> 1.0 as qps -> 0)."""
    pts = sorted((q, m.qos_rate) for q, m in sweep)
    prev_q, prev_r = 0.0, 1.0
    best = 0.0
    for q, r in pts:
        if r >= target:
            best = q
            prev_q, prev_r = q, r
            continue
        if prev_r > target >= r and prev_r > r:
            best = max(best, prev_q + (q - prev_q)
                       * (prev_r - target) / (prev_r - r))
        prev_q, prev_r = q, r
    return best
