from repro.core.cost_model import (CPU_3990X, TPU_V5E_POD, CodeVersion,
                                   GemmLayer, HardwareSpec, Interference,
                                   latency, units_required)
from repro.core.multiversion import VersionSet, compile_layer, compile_model
from repro.core.layer_block import (LayerBlock, ModelPlan, form_blocks,
                                    make_model_plan, next_block)
from repro.core.scheduler import (ChunkPlan, FixedBlockPolicy,
                                  LayerWisePolicy, ModelWisePolicy,
                                  Policy, PremaPolicy, TaskState,
                                  VeltairPolicy)
from repro.core.allocator import UnitPool
from repro.core.interference import (LinearProxy, calibrate_proxy,
                                     pca_variance, pressure_on)

__all__ = [
    "CPU_3990X", "TPU_V5E_POD", "CodeVersion", "GemmLayer", "HardwareSpec",
    "Interference", "latency", "units_required", "VersionSet",
    "compile_layer", "compile_model", "LayerBlock", "ModelPlan",
    "form_blocks", "make_model_plan", "next_block", "ChunkPlan",
    "FixedBlockPolicy", "LayerWisePolicy", "ModelWisePolicy", "Policy",
    "PremaPolicy", "TaskState", "VeltairPolicy", "UnitPool", "LinearProxy",
    "calibrate_proxy", "pca_variance", "pressure_on",
]
