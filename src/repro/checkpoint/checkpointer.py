"""Sharded checkpointing: atomic, async, latest-k, elastic-reshardable.

Layout (one directory per step):

    <dir>/step_000120/
        index.msgpack        tree structure, shapes, dtypes, shard map
        arr_00000.npy ...    one file per leaf (host-gathered)

Writes go to ``step_X.tmp`` and are ``os.replace``d only after fsync — a
crash mid-save never corrupts the latest checkpoint (restore scans for the
newest *committed* step).  ``save_async`` runs the serialization on a
background thread (training continues; ``wait()`` joins before the next
save).  ``restore(..., sharding_tree=...)`` device_puts each leaf with the
*target* sharding, which is what makes restores elastic: a checkpoint
written on a 256-chip mesh restores onto 512 chips (or 1 CPU device) by
just passing that mesh's shardings (repro.checkpoint.elastic).
"""
from __future__ import annotations

import os
import shutil
import threading

import jax
import msgpack
import numpy as np

_INDEX = "index.msgpack"
_COMMIT = "COMMITTED"


def _tree_flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                path = os.path.join(self.dir, name)
                if os.path.exists(os.path.join(path, _COMMIT)):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        leaves, treedef = _tree_flatten_with_paths(tree)
        host_leaves = [np.asarray(l) for l in leaves]
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        meta = {
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)}
                       for l in host_leaves],
            "step": step,
        }
        for i, leaf in enumerate(host_leaves):
            # numpy cannot serialize ml_dtypes (bfloat16 etc.) — store the
            # raw bits and keep the logical dtype in the index
            if leaf.dtype.kind == "V" or str(leaf.dtype) == "bfloat16":
                leaf = leaf.view(np.uint16)
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), leaf)
        with open(os.path.join(tmp, _INDEX), "wb") as f:
            f.write(msgpack.packb(meta))
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, _COMMIT), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def save_async(self, step: int, tree) -> None:
        self.wait()
        # materialize on host before handing to the thread (donation-safe)
        leaves, treedef = _tree_flatten_with_paths(tree)
        host = [np.asarray(l) for l in leaves]
        snapshot = jax.tree_util.tree_unflatten(treedef, host)
        self._thread = threading.Thread(
            target=self.save, args=(step, snapshot), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: int | None, like_tree, sharding_tree=None):
        """Restore into the structure of ``like_tree``; optionally placing
        each leaf with the matching sharding from ``sharding_tree``."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = self._step_dir(step)
        with open(os.path.join(path, _INDEX), "rb") as f:
            meta = msgpack.unpackb(f.read())
        like_leaves, treedef = _tree_flatten_with_paths(like_tree)
        assert meta["n_leaves"] == len(like_leaves), \
            f"leaf count mismatch: ckpt {meta['n_leaves']} vs {len(like_leaves)}"
        sh_leaves = (jax.tree_util.tree_leaves(
            sharding_tree, is_leaf=lambda x: hasattr(x, "device_set"))
            if sharding_tree is not None else [None] * len(like_leaves))
        out = []
        for i, (like, sh) in enumerate(zip(like_leaves, sh_leaves)):
            arr = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
            logical = meta["leaves"][i]["dtype"]
            if logical == "bfloat16" and arr.dtype == np.uint16:
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            arr = arr.astype(like.dtype) if hasattr(like, "dtype") else arr
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
