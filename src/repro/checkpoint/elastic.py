"""Elastic scaling: reshard a checkpoint onto a different mesh.

The checkpoint format is topology-free (host-gathered leaves), so elastic
restore is just ``Checkpointer.restore(sharding_tree=new_mesh_shardings)``.
This module adds the policy layer a cluster controller needs:

  * ``reshard_plan`` — given old/new meshes, report per-leaf shard shape
    changes and total re-layout bytes (the data the restore moves);
  * ``elastic_restore`` — restore the latest checkpoint onto the new mesh,
    validating divisibility (e.g. batch axis vs new data-axis size).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer


@dataclasses.dataclass
class ReshardReport:
    n_leaves: int
    moved_bytes: int
    incompatible: list[str]


def _shards_of(spec: P, mesh: Mesh) -> int:
    n = 1
    for entry in spec:
        if entry is None:
            continue
        for ax in ((entry,) if isinstance(entry, str) else entry):
            n *= mesh.shape[ax]
    return n


def reshard_plan(pspec_tree, old_mesh: Mesh, new_mesh: Mesh,
                 shape_tree) -> ReshardReport:
    moved = 0
    bad: list[str] = []
    specs = jax.tree_util.tree_leaves(
        pspec_tree, is_leaf=lambda x: isinstance(x, P))
    shapes = jax.tree_util.tree_leaves(
        shape_tree, is_leaf=lambda x: isinstance(x, tuple))
    for i, (spec, shape) in enumerate(zip(specs, shapes)):
        old_n = _shards_of(spec, old_mesh)
        new_n = _shards_of(spec, new_mesh)
        size = int(np.prod(shape)) * 2
        if old_n != new_n:
            moved += size
        # divisibility on the sharded dims
        for dim, entry in zip(shape, spec):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            factor = 1
            for ax in axes:
                factor *= new_mesh.shape[ax]
            if dim % factor:
                bad.append(f"leaf{i}: dim {dim} % {factor} != 0")
    return ReshardReport(n_leaves=len(specs), moved_bytes=moved,
                         incompatible=bad)


def elastic_restore(ckpt: Checkpointer, like_tree, pspec_tree,
                    new_mesh: Mesh, step: int | None = None):
    """Restore the latest (or given) step onto ``new_mesh``."""
    shardings = jax.tree_util.tree_map(
        lambda sp: NamedSharding(new_mesh, sp), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))
    return ckpt.restore(step, like_tree, sharding_tree=shardings)
