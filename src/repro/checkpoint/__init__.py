from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.elastic import elastic_restore, reshard_plan

__all__ = ["Checkpointer", "elastic_restore", "reshard_plan"]
