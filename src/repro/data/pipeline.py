"""Deterministic sharded token pipeline.

Sources: synthetic (seeded zipfian tokens — smoke/e2e tests) or a binary
token file (uint16/uint32 memmap).  The pipeline is:

  * deterministic & resumable — batch i is a pure function of (seed, i),
    so restart-after-crash reproduces the exact stream (checkpoint stores
    only the step);
  * shard-aware — each data-parallel host reads only its slice
    (``shard_index / num_shards``), matching the batch's 'data'-axis
    sharding at pod scale.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"      # synthetic | file
    path: str | None = None
    dtype: str = "uint32"
    num_shards: int = 1
    shard_index: int = 0

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._file = None
        if cfg.source == "file":
            self._file = np.memmap(cfg.path, dtype=np.dtype(cfg.dtype),
                                   mode="r")

    def _synthetic_batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + cfg.shard_index)
        # zipf-ish marginal over the vocab (more LM-like than uniform)
        z = rng.zipf(1.3, size=(cfg.shard_batch, cfg.seq_len))
        return np.minimum(z - 1, cfg.vocab_size - 1).astype(np.int32)

    def _file_batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        tokens_per_step = cfg.global_batch * cfg.seq_len
        start = (step * tokens_per_step
                 + cfg.shard_index * cfg.shard_batch * cfg.seq_len)
        n = cfg.shard_batch * cfg.seq_len
        total = len(self._file)
        idx = (start + np.arange(n)) % max(total - 1, 1)
        out = np.asarray(self._file[idx], dtype=np.int32)
        return out.reshape(cfg.shard_batch, cfg.seq_len) % cfg.vocab_size

    def batch(self, step: int) -> dict:
        toks = (self._file_batch(step) if self._file is not None
                else self._synthetic_batch(step))
        return {"tokens": toks}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
