"""Mixture-of-Experts layer: GShard-style top-k routing with capacity.

Token-dropping dispatch/combine einsum formulation (the standard TPU MoE):
tokens are flattened into groups of ``group_size``; each expert accepts
``C = ceil(group_size * top_k * capacity_factor / E)`` tokens per group.
The dispatch tensor is (G, Sg, E, C) so its footprint scales with the group
size, not the global token count.

Supports: shared experts (DeepSeek-V2) and a parallel dense-FFN residual
branch (Arctic) — both handled in the model assembly, not here.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.dist.sharding import hint
from repro.models.params import ParamSpec


def moe_specs(cfg: ModelConfig, moe: MoEConfig) -> dict:
    m, f, e = cfg.d_model, moe.expert_d_ff, moe.num_experts
    return {
        "router": ParamSpec((m, e), jnp.float32, ("embed", None)),
        "w_gate": ParamSpec((e, m, f), axes=("expert", "embed", "mlp")),
        "w_up": ParamSpec((e, m, f), axes=("expert", "embed", "mlp")),
        "w_down": ParamSpec((e, f, m), axes=("expert", "mlp", "embed")),
    }


def _group_size(total_tokens: int, target: int = 512) -> int:
    """Largest divisor of total_tokens that is <= target."""
    best = 1
    for g in range(1, min(target, total_tokens) + 1):
        if total_tokens % g == 0:
            best = g
    return best


def apply_moe(params: dict, x: jax.Array, cfg: ModelConfig, moe: MoEConfig,
              ) -> tuple[jax.Array, jax.Array]:
    """-> (output (B,S,M), aux load-balance loss scalar fp32)."""
    b, s, m = x.shape
    e, k = moe.num_experts, moe.top_k
    total = b * s
    sg = _group_size(total)
    g = total // sg
    xg = x.reshape(g, sg, m)
    xg = hint(xg, ("groups", None, "embed"))

    # --- routing (fp32) ----------------------------------------------------
    logits = jnp.einsum("gsm,me->gse", xg.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,Sg,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (G,Sg,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- capacity dispatch --------------------------------------------------
    cap = max(1, int(math.ceil(sg * k * moe.capacity_factor / e)))
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)    # (G,Sg,k,E)
    flat = onehot.reshape(g, sg * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                      # exclusive
    slot = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                          dtype=jnp.float32) * flat[..., None]  # (G,Sg*k,E,C)
    slot = slot.reshape(g, sg, k, e, cap)
    dispatch = jnp.sum(slot, axis=2)                           # (G,Sg,E,C)
    combine = jnp.sum(slot * gate_vals[..., None, None], axis=2)

    # --- expert compute ------------------------------------------------------
    dsp = dispatch.astype(x.dtype)
    expert_in = jnp.einsum("gsec,gsm->egcm", dsp, xg)          # (E,G,C,M)
    expert_in = hint(expert_in, ("expert", "groups", None, "embed"))
    gate_h = jnp.einsum("egcm,emf->egcf", expert_in,
                        params["w_gate"].astype(x.dtype))
    up_h = jnp.einsum("egcm,emf->egcf", expert_in,
                      params["w_up"].astype(x.dtype))
    act = jax.nn.silu if cfg.activation != "geglu" else (
        lambda a: jax.nn.gelu(a, approximate=True))
    h = act(gate_h.astype(jnp.float32)).astype(x.dtype) * up_h
    expert_out = jnp.einsum("egcf,efm->egcm", h,
                            params["w_down"].astype(x.dtype))
    expert_out = hint(expert_out, ("expert", "groups", None, "embed"))
    out = jnp.einsum("gsec,egcm->gsm", combine.astype(x.dtype), expert_out)

    # --- load-balance auxiliary loss (switch-style) --------------------------
    frac = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1)) / k  # (E,)
    mean_prob = jnp.mean(probs, axis=(0, 1))                   # (E,)
    aux = e * jnp.sum(frac * mean_prob)

    return out.reshape(b, s, m), aux.astype(jnp.float32)
