"""Model assembly: config -> functional Model (init / forward / prefill / decode).

All stacks of identical layers run under ``lax.scan`` with parameters stacked
on a leading "layers" axis (essential to keep 126-layer HLO small).
Heterogeneous structures (deepseek's dense layer 0, recurrentgemma's
(rec, rec, attn) pattern) scan over the repeating unit and unroll remainders.

Inputs dict:
  {"tokens": (B,S) int32}                        LM archs
  {"embeds": (B,S,M), "labels": (B,S) int32}     vlm/audio stub frontends
  optional {"positions": (B,S) or (3,B,S)}       (M-RoPE)
Decode inputs: {"tokens": (B,) } or {"embeds": (B,M)} plus position t —
scalar int32, or (B,) int32 per-row positions (continuous batching).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import hint
from repro.models import layers as L
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rg_mod
from repro.models import ssm as ssm_mod
from repro.models.params import ParamSpec, abstract_params, init_params

PyTree = Any


def cache_batch_axis(path) -> int:
    """Batch axis of a cache leaf: scanned block caches carry a leading
    layer axis, so batch is axis 1 under the ``blocks`` subtree and
    axis 0 everywhere else.  Shared by the serving engine's row
    slice/write helpers and the fused-quantum row masking."""
    return 1 if any(getattr(p, "key", None) == "blocks" for p in path) else 0


def path_keys(path) -> tuple:
    """A tree path as a plain tuple of dict keys (hashable, comparable
    against :meth:`Model.paged_leaf_paths`)."""
    return tuple(getattr(p, "key", None) for p in path)


def stack_specs(tree: PyTree, n: int) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, s.dtype, ("layers",) + s.axes,
                            init=s.init, init_scale=s.init_scale),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# --------------------------------------------------------------------------
# Per-block specs
# --------------------------------------------------------------------------
def _block_specs(cfg: ModelConfig, kind: str) -> dict:
    s: dict = {"ln1": L.norm_specs(cfg)}
    if kind == "dense":
        s["attn"] = L.attention_specs(cfg)
        s["ln2"] = L.norm_specs(cfg)
        s["mlp"] = L.mlp_specs(cfg)
    elif kind == "moe_arctic":
        s["attn"] = L.attention_specs(cfg)
        s["ln2"] = L.norm_specs(cfg)
        s["mlp"] = L.mlp_specs(cfg)                     # dense residual branch
        s["moe"] = moe_mod.moe_specs(cfg, cfg.moe)
    elif kind == "moe_ds":
        s["attn"] = mla_mod.mla_specs(cfg, cfg.mla)
        s["ln2"] = L.norm_specs(cfg)
        s["moe"] = moe_mod.moe_specs(cfg, cfg.moe)
        if cfg.moe.num_shared_experts:
            s["shared"] = L.mlp_specs(cfg, cfg.moe.shared_d_ff)
    elif kind == "ds_dense0":
        s["attn"] = mla_mod.mla_specs(cfg, cfg.mla)
        s["ln2"] = L.norm_specs(cfg)
        s["mlp"] = L.mlp_specs(cfg, cfg.first_dense_d_ff)
    elif kind == "ssm":
        s["mixer"] = ssm_mod.ssm_specs(cfg, cfg.ssm)
    elif kind == "rec":
        s["mixer"] = rg_mod.rglru_specs(cfg, cfg.rglru)
        s["ln2"] = L.norm_specs(cfg)
        s["mlp"] = L.mlp_specs(cfg)
    elif kind == "attn_local":
        s["attn"] = L.attention_specs(cfg)
        s["ln2"] = L.norm_specs(cfg)
        s["mlp"] = L.mlp_specs(cfg)
    else:
        raise ValueError(kind)
    return s


def _attn_cache_specs(cfg: ModelConfig, batch: int, t_max: int) -> dict:
    if cfg.mla is not None:
        r, dr = cfg.mla.kv_lora_rank, cfg.mla.qk_rope_head_dim
        return {"c_kv": ParamSpec((batch, t_max, r), jnp.bfloat16,
                                  ("batch", "seq", "kv_lora"), init="zeros"),
                "k_rope": ParamSpec((batch, t_max, 1, dr), jnp.bfloat16,
                                    ("batch", "seq", None, "head_dim"),
                                    init="zeros")}
    k, d = cfg.num_kv_heads, cfg.head_dim
    return {"k": ParamSpec((batch, t_max, k, d), jnp.bfloat16,
                           ("batch", "seq", "kv_heads", "head_dim"),
                           init="zeros"),
            "v": ParamSpec((batch, t_max, k, d), jnp.bfloat16,
                           ("batch", "seq", "kv_heads", "head_dim"),
                           init="zeros")}


def _block_cache_specs(cfg: ModelConfig, kind: str, batch: int,
                       t_max: int) -> dict:
    if kind in ("dense", "moe_arctic", "moe_ds", "ds_dense0"):
        return _attn_cache_specs(cfg, batch, t_max)
    if kind == "ssm":
        ssm = cfg.ssm
        conv_ch = ssm.d_inner + 2 * ssm.num_groups * ssm.state_dim
        return {
            "conv": ParamSpec((batch, ssm.conv_width - 1, conv_ch),
                              jnp.bfloat16, ("batch", None, "inner"),
                              init="zeros"),
            "ssd": ParamSpec((batch, ssm.num_heads, ssm.head_dim,
                              ssm.state_dim), jnp.float32,
                             ("batch", "inner", None, "state"),
                             init="zeros"),
        }
    if kind == "rec":
        rg = cfg.rglru
        return {
            "h": ParamSpec((batch, rg.lru_width), jnp.float32,
                           ("batch", "inner"), init="zeros"),
            "conv": ParamSpec((batch, rg.conv_width - 1, rg.lru_width),
                              jnp.bfloat16, ("batch", None, "inner"),
                              init="zeros"),
        }
    if kind == "attn_local":
        return rg_mod.window_cache_specs(cfg, batch)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Per-block application
# --------------------------------------------------------------------------
def _apply_block(cfg: ModelConfig, kind: str, params: dict, x: jax.Array, *,
                 positions: jax.Array, cache: dict | None,
                 t: jax.Array | int, valid_len: jax.Array | None = None,
                 page_table: jax.Array | None = None,
                 ) -> tuple[jax.Array, dict | None, jax.Array]:
    """-> (x, new_cache, aux_loss).

    ``valid_len`` (chunked-prefill padding): tokens past it must be exact
    no-ops for carried state.  Recurrent mixers and the window ring cache
    mask explicitly; linear KV caches need nothing — a padded row is
    causally invisible until decode reaches its position, and the decode
    write at that position overwrites it first."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h, new_cache = ssm_mod.mamba2_block(
            params["mixer"], L.apply_norm(params["ln1"], x, cfg.norm_type),
            cfg=cfg, cache=cache, valid_len=valid_len)
        return x + h, new_cache, aux

    if kind == "rec":
        h, new_cache = rg_mod.rglru_block(
            params["mixer"], L.apply_norm(params["ln1"], x, cfg.norm_type),
            cfg=cfg, cache=cache, valid_len=valid_len)
        x = x + h
        m = L.apply_mlp(params["mlp"],
                        L.apply_norm(params["ln2"], x, cfg.norm_type),
                        cfg.activation)
        return x + m, new_cache, aux

    # attention-bearing blocks -------------------------------------------
    xa = L.apply_norm(params["ln1"], x, cfg.norm_type)
    if kind in ("moe_ds", "ds_dense0"):
        h, new_cache = mla_mod.mla_attention(
            params["attn"], xa, cfg=cfg, positions=positions, cache=cache,
            cache_index=t if cache is not None else None,
            page_table=page_table)
    elif kind == "attn_local":
        h, new_cache = _local_attention(cfg, params["attn"], xa,
                                        positions=positions, cache=cache,
                                        t=t, valid_len=valid_len)
    else:
        h, new_cache = L.attention(
            params["attn"], xa, cfg=cfg, positions=positions, cache=cache,
            cache_index=t if cache is not None else None,
            page_table=page_table)
    x = x + h
    x = hint(x, ("batch", "seq", "embed"))
    xm = L.apply_norm(params["ln2"], x, cfg.norm_type)

    if kind in ("dense", "ds_dense0", "attn_local"):
        x = x + L.apply_mlp(params["mlp"], xm, cfg.activation)
    elif kind == "moe_arctic":
        moe_out, aux = moe_mod.apply_moe(params["moe"], xm, cfg, cfg.moe)
        x = x + L.apply_mlp(params["mlp"], xm, cfg.activation) + moe_out
    elif kind == "moe_ds":
        moe_out, aux = moe_mod.apply_moe(params["moe"], xm, cfg, cfg.moe)
        if "shared" in params:
            moe_out = moe_out + L.apply_mlp(params["shared"], xm,
                                            cfg.activation)
        x = x + moe_out
    return x, new_cache, aux


def _local_attention(cfg: ModelConfig, params: dict, x: jax.Array, *,
                     positions: jax.Array, cache: dict | None,
                     t: jax.Array | int, valid_len: jax.Array | None = None,
                     ) -> tuple[jax.Array, dict | None]:
    """RecurrentGemma local-attention layer (window ring-buffer cache)."""
    window = cfg.rglru.window_size
    b, s, _ = x.shape
    q = jnp.einsum("bsm,mhd->bshd", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsm,mkd->bskd", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsm,mkd->bskd", x, params["wv"].astype(x.dtype))
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if cache is not None and s == 1:
        y, new_cache = rg_mod.window_attention_decode(q, cache, k, v, t,
                                                      window)
    elif cache is not None and valid_len is not None:
        # chunked prefill: attend across the ring cache (earlier chunks)
        # and the in-chunk keys; only real tokens are written back
        y, new_cache = rg_mod.window_attention_chunk(q, cache, k, v, t,
                                                     valid_len, window)
    else:
        y = L.attend(q, k, v, q_positions=positions, kv_valid_len=s,
                     window=window)
        new_cache = (rg_mod.fill_window_cache(cache, k, v, window)
                     if cache is not None else None)
    return jnp.einsum("bshd,hdm->bsm", y, params["wo"].astype(x.dtype)), \
        new_cache


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------
@dataclasses.dataclass
class LayerPlan:
    """How cfg.num_layers decomposes into scanned stacks / unrolled layers."""
    prologue: tuple[str, ...]          # unrolled kinds before the scan
    scan_kinds: tuple[str, ...]        # kinds inside one scanned group
    n_groups: int
    epilogue: tuple[str, ...]          # unrolled kinds after the scan


def make_plan(cfg: ModelConfig) -> LayerPlan:
    if cfg.family == "ssm":
        return LayerPlan((), ("ssm",), cfg.num_layers, ())
    if cfg.family == "hybrid":
        pat = tuple("rec" if p == "rec" else "attn_local"
                    for p in cfg.rglru.block_pattern)
        n_groups = cfg.num_layers // len(pat)
        rem = cfg.num_layers - n_groups * len(pat)
        return LayerPlan((), pat, n_groups, pat[:rem])
    if cfg.family == "moe":
        kind = "moe_arctic" if cfg.moe.dense_residual else "moe_ds"
        if cfg.first_dense_layers:
            return LayerPlan(("ds_dense0",) * cfg.first_dense_layers, (kind,),
                             cfg.num_layers - cfg.first_dense_layers, ())
        return LayerPlan((), (kind,), cfg.num_layers, ())
    return LayerPlan((), ("dense",), cfg.num_layers, ())


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan = make_plan(cfg)

    # -- parameters ------------------------------------------------------
    def param_specs(self) -> dict:
        cfg, plan = self.cfg, self.plan
        specs: dict = {"embed": L.embed_specs(cfg)}
        for i, kind in enumerate(plan.prologue):
            specs[f"pro_{i}"] = _block_specs(cfg, kind)
        if plan.n_groups:
            group = {k if len(plan.scan_kinds) == 1 else f"{k}_{j}":
                     _block_specs(cfg, k)
                     for j, k in enumerate(plan.scan_kinds)}
            specs["blocks"] = stack_specs(group, plan.n_groups)
        for i, kind in enumerate(plan.epilogue):
            specs[f"epi_{i}"] = _block_specs(cfg, kind)
        specs["final_norm"] = L.norm_specs(cfg)
        return specs

    def init(self, rng: jax.Array) -> PyTree:
        return init_params(rng, self.param_specs())

    def abstract(self) -> PyTree:
        return abstract_params(self.param_specs())

    # -- caches ------------------------------------------------------------
    def cache_specs(self, batch: int, t_max: int) -> dict:
        cfg, plan = self.cfg, self.plan
        out: dict = {}
        for i, kind in enumerate(plan.prologue):
            out[f"pro_{i}"] = _block_cache_specs(cfg, kind, batch, t_max)
        if plan.n_groups:
            group = {k if len(plan.scan_kinds) == 1 else f"{k}_{j}":
                     _block_cache_specs(cfg, k, batch, t_max)
                     for j, k in enumerate(plan.scan_kinds)}
            out["blocks"] = stack_specs(group, plan.n_groups)
        for i, kind in enumerate(plan.epilogue):
            out[f"epi_{i}"] = _block_cache_specs(cfg, kind, batch, t_max)
        return out

    def init_cache(self, batch: int, t_max: int) -> PyTree:
        cache = init_params(jax.random.PRNGKey(0),
                            self.cache_specs(batch, t_max))
        # ring-buffer position slots start invalid
        def fix(path, leaf):
            if any(getattr(p, "key", None) == "pos" for p in path):
                return jnp.full_like(leaf, -1)
            return jnp.zeros_like(leaf)
        return jax.tree_util.tree_map_with_path(fix, cache)

    # -- paged caches -------------------------------------------------------
    def paged_leaf_paths(self) -> frozenset:
        """Key-paths of cache leaves that page: linear KV leaves, i.e.
        those whose spec carries a ``"seq"`` axis (attention k/v, MLA
        c_kv/k_rope).  Recurrent state (SSM/RG-LRU) and the local-window
        ring cache are O(1)-or-O(window) per slot and stay dense."""
        cached = getattr(self, "_paged_paths", None)
        if cached is None:
            flat, _ = jax.tree_util.tree_flatten_with_path(
                self.cache_specs(1, 8),
                is_leaf=lambda x: isinstance(x, ParamSpec))
            cached = frozenset(path_keys(p) for p, s in flat
                               if "seq" in s.axes)
            self._paged_paths = cached
        return cached

    def all_cache_leaves_paged(self) -> bool:
        """True when every cache leaf pages (pure-attention families).
        Prefix sharing requires this: skipping prefill of a shared prefix
        is only sound when no dense recurrent state would be skipped."""
        flat, _ = jax.tree_util.tree_flatten_with_path(
            self.cache_specs(1, 8),
            is_leaf=lambda x: isinstance(x, ParamSpec))
        paged = self.paged_leaf_paths()
        return bool(paged) and all(path_keys(p) in paged for p, _ in flat)

    def paged_cache_specs(self, batch: int, t_max: int, n_pages: int,
                          page_size: int) -> dict:
        """Cache specs with every ``"seq"``-axis leaf reshaped from dense
        rows ``(batch, t_max, ...)`` to a physical page pool
        ``(n_pages + 1, page_size, ...)`` (index 0 = pinned trash page).
        One logical page uses the same physical index in every layer's
        pool, so a single per-slot page table addresses all layers."""
        if t_max % page_size:
            raise ValueError(f"t_max={t_max} must be a multiple of "
                             f"page_size={page_size}")

        def to_pool(spec):
            if not isinstance(spec, ParamSpec) or "seq" not in spec.axes:
                return spec
            si = spec.axes.index("seq")
            shape = list(spec.shape)
            shape[si - 1] = n_pages + 1        # batch axis -> physical pages
            shape[si] = page_size
            axes = list(spec.axes)
            axes[si - 1], axes[si] = "pages", None
            return ParamSpec(tuple(shape), spec.dtype, tuple(axes),
                             init="zeros")

        return jax.tree_util.tree_map(
            to_pool, self.cache_specs(batch, t_max),
            is_leaf=lambda x: isinstance(x, ParamSpec))

    def init_paged_cache(self, batch: int, t_max: int, n_pages: int,
                         page_size: int) -> PyTree:
        """Paged variant of :meth:`init_cache`.  Adds a per-slot
        ``"page_table"`` leaf (batch, t_max // page_size) int32 of
        physical page indices — all zeros parks every entry on the trash
        page.  The table rides inside the cache pytree so every compiled
        executable (decode, quanta, version-cache entries) is keyed on
        the page-table shape with no signature changes."""
        cache = init_params(
            jax.random.PRNGKey(0),
            self.paged_cache_specs(batch, t_max, n_pages, page_size))

        def fix(path, leaf):
            if any(getattr(p, "key", None) == "pos" for p in path):
                return jnp.full_like(leaf, -1)
            return jnp.zeros_like(leaf)
        cache = jax.tree_util.tree_map_with_path(fix, cache)
        cache["page_table"] = jnp.zeros((batch, t_max // page_size),
                                        jnp.int32)
        return cache

    # -- embedding / head ---------------------------------------------------
    def _embed_inputs(self, params, inputs, positions):
        cfg = self.cfg
        if "embeds" in inputs:
            x = inputs["embeds"].astype(jnp.bfloat16)
        else:
            x = L.embed(params["embed"], inputs["tokens"], cfg)
        if cfg.pos_embed == "sinusoidal":
            pe = L.sinusoidal_pe(
                positions if positions.ndim == 2 else positions[-1],
                cfg.d_model)
            x = x + pe.astype(x.dtype)
        return x

    def _default_positions(self, b: int, s: int, t0: int | jax.Array = 0):
        """Row-contiguous positions from ``t0``: scalar (all rows aligned)
        or (B,) per-row offsets (continuous batching)."""
        t0 = jnp.asarray(t0, jnp.int32)
        if t0.ndim == 1:
            t0 = t0[:, None]
        pos = t0 + jnp.arange(s, dtype=jnp.int32)[None, :]
        pos = jnp.broadcast_to(pos, (b, s))
        if self.cfg.pos_embed == "mrope":
            pos = jnp.broadcast_to(pos[None], (3, b, s))
        return pos

    # -- stacks ------------------------------------------------------------
    def _run_blocks(self, params, x, *, positions, caches, t, remat="none",
                    valid_len=None, page_table=None):
        cfg, plan = self.cfg, self.plan
        aux_total = jnp.zeros((), jnp.float32)
        new_caches: dict = {}

        def group_fn(gp, x, gcache):
            aux_g = jnp.zeros((), jnp.float32)
            ncache: dict = {}
            for j, kind in enumerate(plan.scan_kinds):
                key = kind if len(plan.scan_kinds) == 1 else f"{kind}_{j}"
                c = gcache.get(key) if gcache is not None else None
                x2, nc, a = _apply_block(cfg, kind, gp[key], x,
                                         positions=positions, cache=c, t=t,
                                         valid_len=valid_len,
                                         page_table=page_table)
                x = x2
                aux_g = aux_g + a
                if nc is not None:
                    ncache[key] = nc
            return x, (ncache or None), aux_g

        if remat == "full":
            group_fn = jax.checkpoint(group_fn)
        elif remat == "dots":
            group_fn = jax.checkpoint(
                group_fn,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

        for i, kind in enumerate(plan.prologue):
            c = caches.get(f"pro_{i}") if caches is not None else None
            x, nc, a = _apply_block(cfg, kind, params[f"pro_{i}"], x,
                                    positions=positions, cache=c, t=t,
                                    valid_len=valid_len,
                                    page_table=page_table)
            aux_total += a
            if nc is not None:
                new_caches[f"pro_{i}"] = nc

        if plan.n_groups:
            bcaches = caches.get("blocks") if caches is not None else None

            if bcaches is None and L.ANALYSIS_UNROLL:
                # roofline-analysis mode: unrolled so cost_analysis counts
                # every group (see benchmarks/roofline.py)
                for gi in range(plan.n_groups):
                    gp = jax.tree_util.tree_map(lambda p: p[gi],
                                                params["blocks"])
                    x, _, a = group_fn(gp, x, None)
                    aux_total = aux_total + a
            elif bcaches is None:
                def body(carry, gp):
                    xx, aux = carry
                    xx, _, a = group_fn(gp, xx, None)
                    return (xx, aux + a), None
                (x, aux_total), _ = jax.lax.scan(
                    body, (x, aux_total), params["blocks"])
            elif L.ANALYSIS_UNROLL:
                ncs_list = []
                for gi in range(plan.n_groups):
                    gp = jax.tree_util.tree_map(lambda p: p[gi],
                                                params["blocks"])
                    gc = jax.tree_util.tree_map(lambda c: c[gi], bcaches)
                    x, nc, a = group_fn(gp, x, gc)
                    aux_total = aux_total + a
                    ncs_list.append(nc)
                new_caches["blocks"] = jax.tree_util.tree_map(
                    lambda *cs: jnp.stack(cs), *ncs_list)
            else:
                def body(carry, xs):
                    xx, aux = carry
                    gp, gc = xs
                    xx, nc, a = group_fn(gp, xx, gc)
                    return (xx, aux + a), nc
                (x, aux_total), ncs = jax.lax.scan(
                    body, (x, aux_total), (params["blocks"], bcaches))
                new_caches["blocks"] = ncs

        for i, kind in enumerate(plan.epilogue):
            c = caches.get(f"epi_{i}") if caches is not None else None
            x, nc, a = _apply_block(cfg, kind, params[f"epi_{i}"], x,
                                    positions=positions, cache=c, t=t,
                                    valid_len=valid_len,
                                    page_table=page_table)
            aux_total += a
            if nc is not None:
                new_caches[f"epi_{i}"] = nc
        return x, (new_caches or None), aux_total

    # -- entry points --------------------------------------------------------
    def forward(self, params, inputs, *, positions=None, remat="none"):
        """Full-sequence forward -> (logits (B,S,V) fp32, aux)."""
        cfg = self.cfg
        b, s = (inputs["tokens"].shape if "tokens" in inputs
                else inputs["embeds"].shape[:2])
        if positions is None:
            positions = inputs.get("positions")
        if positions is None:
            positions = self._default_positions(b, s)
        x = self._embed_inputs(params, inputs, positions)
        x = hint(x, ("batch", "seq", "embed"))
        x, _, aux = self._run_blocks(params, x, positions=positions,
                                     caches=None, t=0, remat=remat)
        x = L.apply_norm(params["final_norm"], x, cfg.norm_type)
        return L.unembed(params["embed"], x, cfg), aux

    def loss(self, params, batch, *, remat="none"):
        """Next-token CE (+ MoE aux).  batch needs tokens or embeds+labels."""
        cfg = self.cfg
        logits, aux = self.forward(params, batch, remat=remat)
        if "labels" in batch:
            labels, mask = batch["labels"], batch.get("mask")
            lg = logits
        else:
            tokens = batch["tokens"]
            labels, lg = tokens[:, 1:], logits[:, :-1]
            mask = batch.get("mask")
            mask = mask[:, 1:] if mask is not None else None
        ce = L.cross_entropy(lg, labels, mask)
        aux_w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
        total = ce + aux_w * aux / max(cfg.num_layers, 1)
        return total, {"ce": ce, "aux": aux}

    def prefill(self, params, inputs, cache, *, positions=None):
        """Process a prompt, filling the cache.  -> (last logits (B,V), cache)."""
        cfg = self.cfg
        b, s = (inputs["tokens"].shape if "tokens" in inputs
                else inputs["embeds"].shape[:2])
        if positions is None:
            positions = inputs.get("positions")
        if positions is None:
            positions = self._default_positions(b, s)
        x = self._embed_inputs(params, inputs, positions)
        x, new_cache, _ = self._run_blocks(params, x, positions=positions,
                                           caches=cache, t=0)
        x = L.apply_norm(params["final_norm"], x[:, -1:], cfg.norm_type)
        logits = L.unembed(params["embed"], x, cfg)
        return logits[:, 0], new_cache

    def prefill_chunk(self, params, inputs, cache, t0, valid_len, *,
                      positions=None):
        """Incremental prefill of one fixed-size chunk at absolute start
        position ``t0`` (traced scalar) — the schedulable prefill quantum.

        ``inputs["tokens"]`` is (B, C) with only the first ``valid_len``
        tokens real; the tail is length-bucket padding and is an *exact*
        no-op for all carried state: recurrent mixers (SSM / RG-LRU) mask
        their updates to the last real token, the window ring cache
        refuses pad writes, and a pad row in a linear KV cache is
        causally invisible until the decode step at its position
        overwrites it.  Chaining chunks (t0 = 0, C, 2C, ...) over a
        prompt therefore yields a cache bit-identical to one monolithic
        :meth:`prefill` — while the compiled shapes are the fixed bucket
        set, not the prompt-length distribution.  (Exception: capacity
        MoE routing drops tokens per routing *group*, whose size follows
        the batch shape — so MoE families are chunk-schedule-dependent
        whenever any token exceeds expert capacity, exactly as in any
        chunked-prefill serving system.)

        Returns (logits (B, V) at the last *valid* token, updated cache);
        only the final chunk's logits are meaningful to sample from."""
        cfg = self.cfg
        b, s = (inputs["tokens"].shape if "tokens" in inputs
                else inputs["embeds"].shape[:2])
        t0 = jnp.asarray(t0, jnp.int32)
        vl = jnp.asarray(valid_len, jnp.int32)
        if positions is None:
            positions = inputs.get("positions")
        if positions is None:
            positions = self._default_positions(b, s, t0)
        x = self._embed_inputs(params, inputs, positions)
        x, new_cache, _ = self._run_blocks(params, x, positions=positions,
                                           caches=cache, t=t0, valid_len=vl)
        last = jax.lax.dynamic_slice_in_dim(x, vl - 1, 1, axis=1)
        last = L.apply_norm(params["final_norm"], last, cfg.norm_type)
        logits = L.unembed(params["embed"], last, cfg)
        return logits[:, 0], new_cache

    def decode_step(self, params, inputs, cache, t):
        """One-token decode at absolute position ``t`` — a scalar int32
        (all rows aligned) or a (B,) int32 vector of per-row positions
        (continuous batching: each slot advances independently; attention
        masks each row at its own kv-valid horizon).

        A paged cache (one holding a ``"page_table"`` leaf — see
        :meth:`init_paged_cache`) routes KV reads/writes through the
        per-slot page table; the table itself passes through unchanged
        (the host owns it)."""
        cfg = self.cfg
        t = jnp.asarray(t, jnp.int32)
        page_table = cache.get("page_table") if isinstance(cache, dict) \
            else None
        caches = cache
        if page_table is not None:
            caches = {kk: v for kk, v in cache.items() if kk != "page_table"}
        if "tokens" in inputs:
            b = inputs["tokens"].shape[0]
            toks = inputs["tokens"].reshape(b, 1)
            step_in = {"tokens": toks}
        else:
            b = inputs["embeds"].shape[0]
            step_in = {"embeds": inputs["embeds"].reshape(b, 1, -1)}
        positions = self._default_positions(b, 1, t)
        x = self._embed_inputs(params, step_in, positions)
        x, new_cache, _ = self._run_blocks(params, x, positions=positions,
                                           caches=caches, t=t,
                                           page_table=page_table)
        x = L.apply_norm(params["final_norm"], x, cfg.norm_type)
        logits = L.unembed(params["embed"], x, cfg)
        if page_table is not None:
            new_cache = dict(new_cache)
            new_cache["page_table"] = page_table
        return logits[:, 0], new_cache

    def select_cache_rows(self, live: jax.Array, new_cache: PyTree,
                          old_cache: PyTree) -> PyTree:
        """Per-row cache select: rows where ``live`` is True take
        ``new_cache``, frozen rows keep ``old_cache`` bit-exact.  This is
        what lets a fused multi-step decode freeze finished slots: a
        frozen row's recurrent state (SSM/RG-LRU) and KV writes are fully
        reverted, so its cache is indistinguishable from one that was
        never stepped.

        Page-pool leaves have no per-row batch axis and are kept as
        written: a frozen row replays the *same* KV write at its frozen
        (token, position) — its own pages and dense state are bit-exact
        reverted, so the recomputation is idempotent — and a free row's
        table maps every entry to the pinned trash page."""
        paged = (self.paged_leaf_paths()
                 if isinstance(new_cache, dict) and "page_table" in new_cache
                 else frozenset())

        def sel(path, n, o):
            keys = path_keys(path)
            if keys == ("page_table",) or keys in paged:
                return n
            shape = [1] * n.ndim
            shape[cache_batch_axis(path)] = live.shape[0]
            return jnp.where(live.reshape(shape), n, o).astype(o.dtype)
        return jax.tree_util.tree_map_with_path(sel, new_cache, old_cache)

    def decode_quantum(self, params, tokens, cache, pos, n_left, k: int):
        """Fused on-device decode of up to ``k`` greedy tokens per row.

        A ``lax.scan`` over :meth:`decode_step` — the whole dispatch
        quantum runs as ONE executable with on-device argmax sampling, so
        the host syncs once per quantum instead of once per token.

        Args: ``tokens`` (B,) int32 last-sampled token per row; ``pos``
        (B,) int32 absolute positions; ``n_left`` (B,) int32 per-row step
        budget (rows stop advancing after their budget: token, position
        and cache all freeze, so mid-quantum completions and slots
        shorter than the quantum stay exact).  ``k`` is static — the
        serving layer compiles one executable per K-bucket.

        Returns ``(block (k, B) int32, cache, pos)``; column ``i`` of
        ``block`` is valid for the first ``n_left[i]`` rows.
        """
        def body(carry, j):
            toks, cache_c, pos_c = carry
            logits, new_cache = self.decode_step(
                params, {"tokens": toks}, cache_c, pos_c)
            live = j < n_left
            nxt = jnp.where(live,
                            jnp.argmax(logits, axis=-1).astype(jnp.int32),
                            toks)
            new_cache = self.select_cache_rows(live, new_cache, cache_c)
            pos_c = jnp.where(live, pos_c + 1, pos_c)
            return (nxt, new_cache, pos_c), nxt

        (_, cache, pos), block = jax.lax.scan(
            body,
            (jnp.asarray(tokens, jnp.int32), cache,
             jnp.asarray(pos, jnp.int32)),
            jnp.arange(int(k), dtype=jnp.int32))
        return block, cache, pos

    def _has_nonseq_cache_leaves(self) -> bool:
        """True when any cache leaf carries recurrent / ring state (no
        ``"seq"`` axis) — those leaves need the speculative restore pass."""
        flat, _ = jax.tree_util.tree_flatten_with_path(
            self.cache_specs(1, 8),
            is_leaf=lambda x: isinstance(x, ParamSpec))
        paged = self.paged_leaf_paths()
        return any(path_keys(p) not in paged for p, _ in flat)

    def verify_quantum(self, params, tokens, drafts, cache, pos, n_left):
        """Speculative decode quantum: score a per-row draft block in ONE
        batched forward and greedily accept the longest matching prefix
        plus one corrected token.

        ``tokens`` (B,) is each row's last sampled token, ``drafts``
        (B, d) a drafter's proposed continuation (``d`` static — the
        serving layer compiles one executable per draft depth).  The
        d+1-token sequence [token, draft_0, ..., draft_{d-1}] runs as one
        chunk at per-row start positions ``pos`` (B,) — the same pad-exact
        machinery as :meth:`prefill_chunk`, so a verify forward costs one
        sequence-parallel pass instead of d+1 sequential steps.  Greedy
        acceptance per row: ``accepted`` = length of the longest draft
        prefix matching the model's own argmax, and the row emits
        ``n_emit = min(accepted + 1, n_left)`` tokens (the +1 is the
        corrected/bonus token at the first mismatch; ``n_left`` (B,) is
        the per-row emission budget, 0 freezes a row).

        Rollback of the d+1 optimistic writes is per cache family:

        * linear KV leaves (attention k/v, MLA latents; dense or paged)
          keep the pass-1 writes — entries past ``pos + n_emit`` are
          causally invisible (reads mask ``j <= q_pos``) and the next
          quantum overwrites them before they ever enter a softmax, the
          same argument that makes prefill padding exact.  Paged pools:
          writes beyond the mapped span land on the pinned trash page, so
          the serving layer caps ``n_left`` at the preflighted span.
        * recurrent / ring leaves (SSM conv+ssd, RG-LRU h+conv, local
          window ring) cannot keep optimistic updates, so a second
          forward from the ORIGINAL cache replays the chunk with per-row
          ``valid_len = n_emit``: pads are exact no-ops (dt=0 identity
          recurrence, refused ring writes), leaving each row's state
          bit-identical to stepping exactly ``n_emit`` tokens.  This is
          the functional form of snapshot/restore; it is statically
          skipped for pure linear-KV families.

        Returns ``(block (d+1, B) int32, n_emit (B,), accepted (B,),
        cache, pos)``; column ``i`` of ``block`` holds the row's emitted
        tokens in its first ``n_emit[i]`` entries.
        """
        cfg = self.cfg
        tokens = jnp.asarray(tokens, jnp.int32)
        drafts = jnp.asarray(drafts, jnp.int32)
        pos = jnp.asarray(pos, jnp.int32)
        n_left = jnp.asarray(n_left, jnp.int32)
        b, d = drafts.shape
        s = d + 1
        page_table = cache.get("page_table") if isinstance(cache, dict) \
            else None
        caches = cache
        if page_table is not None:
            caches = {kk: v for kk, v in cache.items() if kk != "page_table"}

        seq = jnp.concatenate([tokens[:, None], drafts], axis=1)  # (B,d+1)
        positions = self._default_positions(b, s, pos)
        x = self._embed_inputs(params, {"tokens": seq}, positions)

        # pass 1: full-validity forward — logits at every candidate
        x1, cache1, _ = self._run_blocks(
            params, x, positions=positions, caches=caches, t=pos,
            valid_len=jnp.full((b,), s, jnp.int32), page_table=page_table)
        h = L.apply_norm(params["final_norm"], x1, cfg.norm_type)
        logits = L.unembed(params["embed"], h, cfg)       # (B,d+1,V) fp32
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B,d+1)

        match = (g[:, :d] == drafts).astype(jnp.int32)
        accepted = jnp.sum(jnp.cumprod(match, axis=1), axis=1)    # (B,)
        n_emit = jnp.minimum(accepted + 1, n_left)
        n_emit = jnp.where(n_left > 0, n_emit, 0)

        if self._has_nonseq_cache_leaves():
            # restore pass: exact recurrent/ring state after n_emit tokens
            _, cache2, _ = self._run_blocks(
                params, x, positions=positions, caches=caches, t=pos,
                valid_len=n_emit, page_table=page_table)
            seq_paths = self.paged_leaf_paths()

            def merge(path, c1, c2):
                return c1 if path_keys(path) in seq_paths else c2
            new_cache = jax.tree_util.tree_map_with_path(
                merge, cache1, cache2)
        else:
            new_cache = cache1
        if page_table is not None:
            new_cache = dict(new_cache)
            new_cache["page_table"] = page_table
        return g.T, n_emit, accepted, new_cache, pos + n_emit


@functools.lru_cache(maxsize=None)
def _cached_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def build_model(cfg: ModelConfig) -> Model:
    return _cached_model(cfg)
