"""Multi-head Latent Attention (DeepSeek-V2).

KV is compressed to a ``kv_lora_rank`` latent (plus a single shared RoPE key
head), which is what gets cached: 512+64 dims/token instead of
2*H*head_dim.  Two decode paths:

  * plain    — cached latents are re-expanded through W_uk/W_uv each step
               (faithful to the algebra, heavy at long context)
  * absorbed — W_uk is folded into the query and W_uv into the output
               projection, so attention runs directly in latent space.
               O(H*T*(lora+rope)) instead of O(T*lora*H*(dn+dv)) per step.
               This is a beyond-paper decode optimization (EXPERIMENTS.md
               §Perf, deepseek decode_32k hillclimb).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import NEG_INF, apply_norm, apply_rope, norm_specs
from repro.models.params import ParamSpec


def mla_specs(cfg: ModelConfig, mla: MLAConfig) -> dict:
    m, h = cfg.d_model, cfg.num_heads
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    r = mla.kv_lora_rank
    return {
        "wq": ParamSpec((m, h, dn + dr), axes=("embed", "heads", "head_dim")),
        "w_dkv": ParamSpec((m, r + dr), axes=("embed", "kv_lora")),
        "kv_norm": norm_specs(cfg, r),
        "w_uk": ParamSpec((r, h, dn), axes=("kv_lora", "heads", "head_dim")),
        "w_uv": ParamSpec((r, h, dv), axes=("kv_lora", "heads", "head_dim")),
        "wo": ParamSpec((h, dv, m), axes=("heads", "head_dim", "embed")),
    }


def _compress(params, x, cfg: ModelConfig, mla: MLAConfig, positions):
    """x -> (c_kv (B,S,r) normalized, k_rope (B,S,1,dr) rotated)."""
    r, dr = mla.kv_lora_rank, mla.qk_rope_head_dim
    ckv_full = jnp.einsum("bsm,mr->bsr", x, params["w_dkv"].astype(x.dtype))
    c_kv = apply_norm(params["kv_norm"], ckv_full[..., :r], cfg.norm_type)
    k_rope = ckv_full[..., r:][:, :, None, :]          # (B,S,1,dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def _queries(params, x, cfg: ModelConfig, mla: MLAConfig, positions):
    dn, dr = mla.qk_nope_head_dim, mla.qk_rope_head_dim
    q = jnp.einsum("bsm,mhd->bshd", x, params["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mask(t: int, q_positions: jax.Array, kv_valid_len) -> jax.Array:
    j = jnp.arange(t)[None, None, :]
    mask = j <= q_positions[:, :, None]
    kvl = jnp.asarray(kv_valid_len)
    mask &= j < (kvl if kvl.ndim == 0 else kvl.reshape(-1, 1, 1))
    return mask                                        # (B,S,T)


def mla_attention(params: dict, x: jax.Array, *, cfg: ModelConfig,
                  positions: jax.Array, cache: dict | None = None,
                  cache_index: jax.Array | None = None,
                  page_table: jax.Array | None = None,
                  ) -> tuple[jax.Array, dict | None]:
    """MLA self-attention; cache = {"c_kv": (B,T,r), "k_rope": (B,T,1,dr)}.

    With ``page_table`` (B, pages_per_slot) the cached latents live in
    physical page pools ``(n_pages + 1, page_size, ...)``: decode scatters
    the new latent into its slot's page and gathers the full horizon
    through the table (latents are already memory-compressed, so the
    gather reference path is the paged MLA path — no kernel variant)."""
    mla = cfg.mla
    b, s, m = x.shape
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    scale = (dn + dr) ** -0.5

    q_nope, q_rope = _queries(params, x, cfg, mla, positions)
    c_kv, k_rope = _compress(params, x, cfg, mla, positions)

    if cache is None:
        ckv_all, krope_all, kv_len = c_kv, k_rope, s
        new_cache = None
    elif page_table is not None:
        idx = jnp.broadcast_to(
            jnp.asarray(cache_index, jnp.int32).reshape(-1), (b,))
        ps_sz = cache["c_kv"].shape[1]
        if s == 1:
            bidx = jnp.arange(b, dtype=jnp.int32)
            phys = page_table[bidx, idx // ps_sz]
            off = idx % ps_sz
            ckv_pool = cache["c_kv"].at[phys, off].set(
                c_kv[:, 0].astype(cache["c_kv"].dtype))
            krope_pool = cache["k_rope"].at[phys, off].set(
                k_rope[:, 0].astype(cache["k_rope"].dtype))
        else:
            # multi-token (speculative verify): scatter each row's S new
            # latents through the table; unmapped spans hit the trash page
            rows = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
            phys = page_table[bidx, rows // ps_sz]   # (B,S)
            off = rows % ps_sz
            ckv_pool = cache["c_kv"].at[phys, off].set(
                c_kv.astype(cache["c_kv"].dtype))
            krope_pool = cache["k_rope"].at[phys, off].set(
                k_rope.astype(cache["k_rope"].dtype))
        new_cache = {"c_kv": ckv_pool, "k_rope": krope_pool}
        n_slot = page_table.shape[1]
        ckv_all = ckv_pool[page_table].reshape(
            b, n_slot * ps_sz, *ckv_pool.shape[2:])
        krope_all = krope_pool[page_table].reshape(
            b, n_slot * ps_sz, *krope_pool.shape[2:])
        kv_len = idx + s
    else:
        idx = jnp.asarray(cache_index, jnp.int32)
        if idx.ndim:
            # per-row positions (continuous batching): scatter each row's
            # latents at its own index, per-row kv-valid horizon
            rows = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
            ckv_all = cache["c_kv"].at[bidx, rows].set(
                c_kv.astype(cache["c_kv"].dtype))
            krope_all = cache["k_rope"].at[bidx, rows].set(
                k_rope.astype(cache["k_rope"].dtype))
        else:
            ckv_all = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
                (0, idx, 0))
            krope_all = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                (0, idx, 0, 0))
        kv_len = idx + s
        new_cache = {"c_kv": ckv_all, "k_rope": krope_all}
    t = ckv_all.shape[1]
    w_uk = params["w_uk"].astype(x.dtype)
    w_uv = params["w_uv"].astype(x.dtype)

    if mla.absorb:
        def attn_chunk(q_nope_c, q_rope_c, pos_c):
            # fold W_uk into q: q_lat (B,C,H,r); score against raw latents.
            mask = _mask(t, pos_c, kv_len)[:, None]
            q_lat = jnp.einsum("bshd,rhd->bshr", q_nope_c, w_uk)
            s_nope = jnp.einsum("bshr,btr->bhst",
                                q_lat.astype(jnp.float32),
                                ckv_all.astype(jnp.float32))
            s_rope = jnp.einsum("bshd,btzd->bhst",
                                q_rope_c.astype(jnp.float32),
                                krope_all.astype(jnp.float32))
            scores = jnp.where(mask, (s_nope + s_rope) * scale, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            ctx = jnp.einsum("bhst,btr->bshr", probs,
                             ckv_all.astype(jnp.float32))   # (B,C,H,r)
            return jnp.einsum("bshr,rhd->bshd", ctx.astype(x.dtype), w_uv)
    else:
        k_nope = jnp.einsum("btr,rhd->bthd", ckv_all, w_uk)   # (B,T,H,dn)
        v = jnp.einsum("btr,rhd->bthd", ckv_all, w_uv)        # (B,T,H,dv)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_all,
                                      (b, t, cfg.num_heads, dr))], axis=-1)

        def attn_chunk(q_nope_c, q_rope_c, pos_c):
            mask = _mask(t, pos_c, kv_len)[:, None]
            q = jnp.concatenate([q_nope_c, q_rope_c], axis=-1)
            scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                                k_full.astype(jnp.float32)) * scale
            scores = jnp.where(mask, scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            return jnp.einsum("bhst,bthd->bshd", probs,
                              v.astype(jnp.float32)).astype(x.dtype)

    from repro.models import layers as _L
    from repro.models.layers import SCORE_CHUNK_ELEMS, _chunk_len
    if s * t <= SCORE_CHUNK_ELEMS or s == 1:
        y = attn_chunk(q_nope, q_rope, positions)
    else:
        cs = _chunk_len(s, t)
        n = s // cs

        def split(a):
            return jnp.moveaxis(a.reshape(b, n, cs, *a.shape[2:]), 1, 0)

        qn, qr, ps = split(q_nope), split(q_rope), split(positions)
        if _L.ANALYSIS_UNROLL:
            out = jnp.stack([attn_chunk(qn[i], qr[i], ps[i])
                             for i in range(n)])
        else:
            out = jax.lax.map(lambda args: attn_chunk(*args), (qn, qr, ps))
        y = jnp.moveaxis(out, 0, 1).reshape(b, s, cfg.num_heads, -1)
    out = jnp.einsum("bshd,hdm->bsm", y, params["wo"].astype(x.dtype))
    return out, new_cache
