"""Modality frontends (STUBS per assignment) and input-spec builders.

``[vlm]``/``[audio]`` archs specify the transformer backbone only; the
frontend provides *precomputed* patch/frame embeddings:
  qwen2-vl  -> patch embeddings (B,S,M) + 3D M-RoPE positions (3,B,S)
  musicgen  -> EnCodec frame embeddings (B,S,M) (sum of codebook embeds)

``input_specs(cfg, shape)`` returns a ParamSpec pytree describing every model
input for that (arch x shape) cell — the dry-run lowers against
``jax.ShapeDtypeStruct`` stand-ins derived from it (no allocation), smoke
tests materialize small concrete samples from the same description.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.params import ParamSpec


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s, m = shape.global_batch, shape.seq_len, cfg.d_model
    if shape.mode == "decode":
        if cfg.frontend != "none":
            return {"embeds": ParamSpec((b, m), jnp.bfloat16,
                                        ("batch", "embed"))}
        return {"tokens": ParamSpec((b,), jnp.int32, ("batch",))}

    specs: dict = {}
    if cfg.frontend != "none":
        specs["embeds"] = ParamSpec((b, s, m), jnp.bfloat16,
                                    ("batch", "seq", "embed"))
        if cfg.pos_embed == "mrope":
            specs["positions"] = ParamSpec((3, b, s), jnp.int32,
                                           (None, "batch", "seq"))
        if shape.mode == "train":
            specs["labels"] = ParamSpec((b, s), jnp.int32, ("batch", "seq"))
    else:
        specs["tokens"] = ParamSpec((b, s), jnp.int32, ("batch", "seq"))
    return specs


def abstract_inputs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    return jax.tree_util.tree_map(
        lambda sp: sp.abstract(), input_specs(cfg, shape),
        is_leaf=lambda x: isinstance(x, ParamSpec))


def make_sample_inputs(cfg: ModelConfig, shape: ShapeConfig,
                       seed: int = 0) -> dict:
    """Small concrete batch for smoke tests / examples."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, sp in input_specs(cfg, shape).items():
        if sp.dtype == jnp.int32:
            if name in ("tokens", "labels"):
                out[name] = jnp.asarray(
                    rng.integers(0, cfg.vocab_size, sp.shape), jnp.int32)
            else:  # positions
                s = sp.shape[-1]
                pos = np.broadcast_to(np.arange(s, dtype=np.int32), sp.shape)
                out[name] = jnp.asarray(pos)
        else:
            out[name] = jnp.asarray(
                0.02 * rng.standard_normal(sp.shape), sp.dtype)
    return out
