"""Parameter-spec infrastructure.

Models declare their parameters as a pytree of :class:`ParamSpec` (shape, dtype,
*logical axes*, initializer).  This lets us:

  * materialize real arrays (``init_params``) for smoke tests / examples,
  * build ``jax.ShapeDtypeStruct`` stand-ins (``abstract_params``) for the
    multi-pod dry-run without allocating 480B-parameter models,
  * derive ``PartitionSpec`` trees from logical-axis -> mesh-axis rule tables
    (see ``repro.dist.sharding``) for any mesh.

Logical axis vocabulary (used by the sharding rules):
  "embed"     d_model
  "vocab"     vocabulary
  "heads"     attention query heads
  "kv_heads"  attention kv heads
  "head_dim"  per-head dim
  "mlp"       ffn hidden
  "expert"    MoE expert axis
  "kv_lora"   MLA latent dim
  "inner"     SSM / RG-LRU inner width
  "state"     SSM state dim
  "conv"      short conv width
  "layers"    stacked (scanned) layer axis -- never sharded
  None        replicated axis
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    dtype: Any = jnp.bfloat16
    axes: tuple[str | None, ...] = ()
    init: str = "normal"          # normal | zeros | ones | scaled_normal | embed
    init_scale: float | None = None  # stddev override

    def __post_init__(self):
        if len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank mismatch with shape {self.shape}")

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    # fan-in scaled normal by default; embed uses 1.0 stddev like most LMs.
    if spec.init_scale is not None:
        std = spec.init_scale
    elif spec.init == "embed":
        std = 0.02
    else:
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.size, 1)
        # stacked-layer params: fan-in excludes the leading "layers" axis
        if spec.axes and spec.axes[0] == "layers" and len(spec.shape) >= 3:
            fan_in = spec.shape[1]
        std = float(fan_in) ** -0.5
    out = std * jax.random.normal(key, spec.shape, jnp.float32)
    return out.astype(spec.dtype)


def init_params(rng: jax.Array, specs: PyTree) -> PyTree:
    """Materialize a param pytree from specs, keyed deterministically by path."""
    seed = int(jax.random.randint(rng, (), 0, 2**31 - 1))

    def one(path, spec: ParamSpec):
        h = int.from_bytes(
            hashlib.sha256(_path_str(path).encode()).digest()[:4], "little")
        key = jax.random.PRNGKey(np.uint32((seed + h) % (2**31)))
        return _init_one(spec, key)

    return jax.tree_util.tree_map_with_path(
        one, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract_params(specs: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: s.abstract(), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(specs: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(l.size for l in leaves)


def param_bytes(specs: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(l.size * jnp.dtype(l.dtype).itemsize for l in leaves)


def map_axes(specs: PyTree, fn: Callable[[tuple[str | None, ...]], Any]) -> PyTree:
    """Map each ParamSpec's logical axes through ``fn`` (e.g. -> PartitionSpec)."""
    return jax.tree_util.tree_map(
        lambda s: fn(s.axes), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))
