"""Mamba-2 block: chunked SSD (state-space duality) scan.

Recurrence (per head, state (P=head_dim, N=state_dim)):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * (x_t  B_t^T)      (outer product)
    y_t = C_t . h_t + D * x_t

The chunked algorithm splits the sequence into chunks of Q tokens:
intra-chunk contributions are a masked (Q,Q) matmul (attention-like, MXU
friendly — Pallas kernel in repro.kernels.ssd_scan), inter-chunk state is a
cheap scan over chunk summaries.  Reference math here is pure jnp; the
kernel is validated against it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.params import ParamSpec
from repro.models.layers import norm_specs, apply_norm


def ssm_specs(cfg: ModelConfig, ssm: SSMConfig) -> dict:
    m = cfg.d_model
    di, g, n, nh = ssm.d_inner, ssm.num_groups, ssm.state_dim, ssm.num_heads
    conv_ch = di + 2 * g * n
    d_in_proj = 2 * di + 2 * g * n + nh
    return {
        "in_proj": ParamSpec((m, d_in_proj), axes=("embed", "inner")),
        "conv_w": ParamSpec((ssm.conv_width, conv_ch), jnp.float32,
                            ("conv", "inner")),
        "conv_b": ParamSpec((conv_ch,), jnp.float32, ("inner",), init="zeros"),
        "A_log": ParamSpec((nh,), jnp.float32, (None,), init="zeros"),
        "dt_bias": ParamSpec((nh,), jnp.float32, (None,), init="zeros"),
        "D": ParamSpec((nh,), jnp.float32, (None,), init="ones"),
        "norm": norm_specs(cfg, di),
        "out_proj": ParamSpec((di, m), axes=("inner", "embed")),
    }


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: jax.Array | None = None,
                  valid_len: jax.Array | None = None,
                  ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  x (B,S,C), w (W,C).  state (B,W-1,C) holds the
    trailing context from previous steps.  Returns (y, new_state).

    ``valid_len`` (traced scalar, or (B,) vector for per-row validity —
    the speculative verify path accepts a different number of tokens per
    row): only the first ``valid_len`` tokens of ``x`` are real — the
    returned state is the trailing context as of that token, so bucket
    padding never leaks into later chunks or decode steps.  (Conv
    *outputs* at padded positions are garbage; callers discard them.)"""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)   # (B,S+W-1,C)
    # sum_w xp[:, t + i, c] * w[i, c]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(width))
    y = y + b.astype(x.dtype)
    if width <= 1:
        new_state = state
    elif valid_len is None:
        new_state = xp[:, -(width - 1):, :]
    else:
        # xp index of real token i is (W-1)+i, so the W-1 entries that
        # precede real position valid_len start at xp index valid_len
        vl = jnp.asarray(valid_len, jnp.int32)
        if vl.ndim:
            bidx = jnp.arange(x.shape[0], dtype=jnp.int32)[:, None]
            rows = vl[:, None] + jnp.arange(width - 1, dtype=jnp.int32)
            new_state = xp[bidx, rows]
        else:
            new_state = jax.lax.dynamic_slice_in_dim(
                xp, vl, width - 1, axis=1)
    return y, new_state


def ssd_reference(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                  c: jax.Array, *, chunk_size: int,
                  initial_state: jax.Array | None = None,
                  ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (pure-jnp oracle).

    x  (B,L,H,P)   inputs per head
    dt (B,L,H)     softplus'd step sizes (fp32)
    a  (H,)        negative decay rates A (fp32, a<0)
    b  (B,L,H,N)   input projections (already broadcast group->head)
    c  (B,L,H,N)   output projections
    -> (y (B,L,H,P), final_state (B,H,P,N))
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    q = chunk_size
    orig_l = l
    if l % q:
        # zero-dt padding is exact: decay exp(0*a)=1, input contribution 0.
        pad = q - l % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = x.shape[1]
    nc = l // q
    xf = x.astype(jnp.float32).reshape(bsz, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, q, h)
    bf = b.astype(jnp.float32).reshape(bsz, nc, q, h, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, q, h, n)

    da = dtf * a                                   # (B,NC,Q,H) log-decay <0
    seg = jnp.cumsum(da, axis=2)                   # inclusive cumsum
    total = seg[:, :, -1:, :]                      # (B,NC,1,H)

    # intra-chunk: y[i] += sum_{j<=i} exp(seg_i - seg_j) (C_i.B_j) dt_j x_j
    decay = seg[:, :, :, None, :] - seg[:, :, None, :, :]   # (B,NC,Qi,Qj,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    gate = jnp.where(causal[None, None, :, :, None], jnp.exp(decay), 0.0)
    cb = jnp.einsum("bkihn,bkjhn->bkijh", cf, bf)
    m_att = cb * gate * dtf[:, :, None, :, :]
    y_intra = jnp.einsum("bkijh,bkjhp->bkihp", m_att, xf)

    # chunk summary states: S_k = sum_j exp(total - seg_j) dt_j B_j x_j^T
    w = jnp.exp(total - seg) * dtf                 # (B,NC,Q,H)
    s_chunk = jnp.einsum("bkjh,bkjhn,bkjhp->bkhpn", w, bf, xf)

    # inter-chunk recurrence over chunk index
    init = (jnp.zeros((bsz, h, p, n), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))

    def step(carry, inp):
        s_k, tot_k = inp                           # (B,H,P,N), (B,H)
        state_in = carry
        state_out = jnp.exp(tot_k)[:, :, None, None] * state_in + s_k
        return state_out, state_in

    tot = total[:, :, 0, :]                        # (B,NC,H)
    from repro.models import layers as _L
    if _L.ANALYSIS_UNROLL:
        carry = init
        ins = []
        for ci in range(nc):
            carry, prev = step(carry, (s_chunk[:, ci], tot[:, ci]))
            ins.append(prev)
        final, states_in = carry, jnp.stack(ins, axis=1)
    else:
        final, states_in = jax.lax.scan(
            step, init,
            (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(tot, 1, 0)))
        states_in = jnp.moveaxis(states_in, 0, 1)  # (B,NC,H,P,N) entering

    # inter-chunk output: y[i] += C_i . (exp(seg_i) * state_in)
    y_inter = jnp.einsum("bkihn,bkih,bkhpn->bkihp", cf, jnp.exp(seg),
                         states_in)
    y = (y_intra + y_inter).reshape(bsz, l, h, p)[:, :orig_l]
    return y.astype(x.dtype), final


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    a: jax.Array, b: jax.Array, c: jax.Array,
                    ) -> tuple[jax.Array, jax.Array]:
    """One-token SSD update.  state (B,H,P,N); x (B,H,P); dt (B,H);
    b,c (B,H,N).  -> (y (B,H,P), new_state)."""
    sf = state.astype(jnp.float32)
    da = jnp.exp(dt.astype(jnp.float32) * a)       # (B,H)
    upd = (dt.astype(jnp.float32)[..., None, None]
           * x.astype(jnp.float32)[..., None] * b[:, :, None, :])
    new_state = da[..., None, None] * sf + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c.astype(jnp.float32))
    return y.astype(x.dtype), new_state


def _split_proj(zxbcdt: jax.Array, ssm: SSMConfig):
    di, g, n, nh = ssm.d_inner, ssm.num_groups, ssm.state_dim, ssm.num_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    return z, xbc, dt


def _expand_groups(t: jax.Array, nh: int) -> jax.Array:
    """(B,S,G,N) -> (B,S,H,N) by repeating each group H/G times."""
    b, s, g, n = t.shape
    rep = nh // g
    return jnp.repeat(t, rep, axis=2) if rep > 1 else t


def mamba2_block(params: dict, x: jax.Array, *, cfg: ModelConfig,
                 cache: dict | None = None,
                 valid_len: jax.Array | None = None,
                 ) -> tuple[jax.Array, dict | None]:
    """Full Mamba-2 mixer.  cache = {"conv": (B,W-1,C), "ssd": (B,H,P,N)}.

    ``valid_len`` (traced scalar, or (B,) vector for per-row validity —
    the speculative verify restore pass): chunked-prefill padding support
    — the tokens past ``valid_len`` get dt=0, which makes them *exact*
    no-ops for the SSD state (decay exp(0*a)=1, input contribution
    dt*... = 0), and the conv state is taken as of the last real token."""
    ssm = cfg.ssm
    bsz, s, _ = x.shape
    di, g, n, nh, p = (ssm.d_inner, ssm.num_groups, ssm.state_dim,
                       ssm.num_heads, ssm.head_dim)
    zxbcdt = jnp.einsum("bsm,md->bsd", x, params["in_proj"].astype(x.dtype))
    z, xbc, dt = _split_proj(zxbcdt, ssm)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = causal_conv1d(xbc, params["conv_w"], params["conv_b"],
                                  conv_state,
                                  valid_len=(valid_len if cache is not None
                                             else None))
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    x_ssm = xbc[..., :di].reshape(bsz, s, nh, p)
    b_mat = _expand_groups(xbc[..., di:di + g * n].reshape(bsz, s, g, n), nh)
    c_mat = _expand_groups(xbc[..., di + g * n:].reshape(bsz, s, g, n), nh)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    if valid_len is not None:
        vl = jnp.asarray(valid_len, jnp.int32)
        offs = jnp.arange(s, dtype=jnp.int32)
        live = ((offs[None, :] < vl[:, None]) if vl.ndim
                else (offs < vl)[None, :])
        dtv = jnp.where(live[:, :, None], dtv, 0.0)
    a = -jnp.exp(params["A_log"])

    if cache is not None and s == 1:
        y1, new_ssd = ssd_decode_step(
            cache["ssd"], x_ssm[:, 0], dtv[:, 0], a,
            b_mat[:, 0].astype(jnp.float32), c_mat[:, 0].astype(jnp.float32))
        y = y1[:, None]
    else:
        from repro.kernels import dispatch
        fn = dispatch.get_ssd()
        init = cache["ssd"] if cache is not None else None
        if fn is not None:
            y, new_ssd = fn(x_ssm, dtv, a, b_mat, c_mat,
                            chunk_size=ssm.chunk_size, initial_state=init)
        else:
            y, new_ssd = ssd_reference(x_ssm, dtv, a, b_mat, c_mat,
                                       chunk_size=ssm.chunk_size,
                                       initial_state=init)
    y = y + (params["D"][:, None] * x_ssm.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(bsz, s, di)
    # gated RMSNorm then out-projection
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = apply_norm(params["norm"], y, cfg.norm_type)
    out = jnp.einsum("bsd,dm->bsm", y, params["out_proj"].astype(x.dtype))
    new_cache = ({"conv": new_conv, "ssd": new_ssd}
                 if cache is not None else None)
    return out, new_cache
