"""Common model layers (pure JAX, functional).

Every layer is a pair of functions:
  ``*_specs(cfg) -> pytree[ParamSpec]``   parameter declaration
  ``apply(params, x, ...) -> y``          application

Conventions:
  x           (B, S, M)    activations, bf16
  q           (B, S, H, D)
  k, v        (B, T, K, D) K = kv heads
  positions   (B, S) int32, or (3, B, S) for M-RoPE
  softmax / norms / rope run in fp32 and cast back.

Attention math lives here as the XLA reference path; the Pallas flash kernel
(repro.kernels) is validated against it and selected via repro.kernels.dispatch.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec

NEG_INF = -2.3819763e38  # large negative, bf16-safe after cast


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def norm_specs(cfg: ModelConfig, width: int | None = None) -> dict:
    w = width or cfg.d_model
    if cfg.norm_type == "layernorm":
        return {"scale": ParamSpec((w,), jnp.float32, ("embed",), init="ones"),
                "bias": ParamSpec((w,), jnp.float32, ("embed",), init="zeros")}
    return {"scale": ParamSpec((w,), jnp.float32, ("embed",), init="ones")}


def apply_norm(params: dict, x: jax.Array, norm_type: str = "rmsnorm",
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + eps)
        out = out * params["scale"] + params["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary embeddings (RoPE and qwen2-vl M-RoPE)
# --------------------------------------------------------------------------
def _rope_angles(positions: jax.Array, head_dim: int, theta: float,
                 mrope_sections: tuple[int, int, int] | None) -> jax.Array:
    """-> (B, S, D/2) fp32 angles."""
    half = head_dim // 2
    freq_idx = jnp.arange(half, dtype=jnp.float32)
    inv_freq = theta ** (-2.0 * freq_idx / head_dim)   # (half,)
    if mrope_sections is None:
        pos = positions.astype(jnp.float32)            # (B, S)
        return pos[..., None] * inv_freq               # (B, S, half)
    # M-RoPE: positions (3, B, S) for (t, h, w); frequency bands are assigned
    # to sections [0:s0] -> t, [s0:s0+s1] -> h, rest -> w.
    s0, s1, s2 = mrope_sections
    assert s0 + s1 + s2 == half, (mrope_sections, half)
    posf = positions.astype(jnp.float32)               # (3, B, S)
    sel = jnp.concatenate([
        jnp.zeros((s0,), jnp.int32),
        jnp.ones((s1,), jnp.int32),
        jnp.full((s2,), 2, jnp.int32)])                # (half,)
    pos_sel = jnp.take(posf, sel, axis=0)              # (half, B, S)
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)             # (B, S, half)
    return pos_sel * inv_freq


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: tuple[int, int, int] | None = None) -> jax.Array:
    """x: (B, S, H, D). Split-halves convention (llama / gemma)."""
    d = x.shape[-1]
    ang = _rope_angles(positions, d, theta, mrope_sections)  # (B,S,half)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : d // 2], xf[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pe(positions: jax.Array, width: int) -> jax.Array:
    """(B, S) -> (B, S, width) fp32 sinusoidal position encoding."""
    half = width // 2
    freq = jnp.exp(-math.log(10000.0)
                   * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# Attention (GQA / MQA / MHA; causal; optional sliding window)
# --------------------------------------------------------------------------
def attention_specs(cfg: ModelConfig) -> dict:
    m, h, k, d = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((m, h, d), axes=("embed", "heads", "head_dim")),
        "wk": ParamSpec((m, k, d), axes=("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((m, k, d), axes=("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, d, m), axes=("heads", "head_dim", "embed")),
    }


# max fp32 score elements per (q-chunk x T) slab — bounds the transient
# attention buffer on the XLA reference path (the Pallas kernel tiles in
# VMEM instead); 4M => <=1 GiB/chip-class transients at 32k context.
SCORE_CHUNK_ELEMS = 1 << 22

# Roofline-analysis mode: XLA cost_analysis counts while-loop bodies ONCE
# (no trip-count multiply), so benchmarks/roofline.py lowers depth-reduced
# models with every lax.scan/map replaced by an unrolled python loop.
ANALYSIS_UNROLL = False


def _attend_core(q, k, v, *, q_positions, kv_valid_len, window, softcap):
    from repro.dist.sharding import hint
    b, s, h, d = q.shape
    t = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    qf = q.reshape(b, s, kh, g, d).astype(jnp.float32) * (d ** -0.5)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32))
    # keep scores sharded like the KV sequence (stops GSPMD from
    # all-gathering a seq-sharded cache; softmax runs as partial max/sum)
    scores = hint(scores, ("batch", None, None, None, "seq"))
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    j = jnp.arange(t)[None, None, :]                      # (1, 1, T)
    qpos = q_positions[:, :, None]                        # (B, S, 1)
    mask = j <= qpos
    if window is not None:
        mask &= j > qpos - window
    if not isinstance(kv_valid_len, int) or kv_valid_len < t:
        kvl = jnp.asarray(kv_valid_len)
        mask &= j < kvl.reshape(-1, 1, 1) if kvl.ndim else j < kvl
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def _chunk_len(s: int, t: int, budget: int = SCORE_CHUNK_ELEMS) -> int:
    """Largest divisor of s with chunk*t <= budget (>=1)."""
    target = max(budget // max(t, 1), 1)
    best = 1
    for c in range(1, min(target, s) + 1):
        if s % c == 0:
            best = c
    return best


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           q_positions: jax.Array, kv_valid_len: jax.Array | int,
           window: int | None = None, softcap: float | None = None,
           use_kernel_hook: bool = True) -> jax.Array:
    """Masked GQA attention.

    q: (B, S, H, D); k/v: (B, T, K, D).  q_positions (B, S): absolute position
    of each query token (so decode passes S=1 with its position).  kv slot j
    holds absolute position j; slots >= kv_valid_len are invalid (future cache
    slots).  Causal: attend to j <= q_pos; window w: j > q_pos - w.

    Long sequences run q-chunked (lax.map over query blocks) so the fp32
    score transient stays bounded at 32k/500k context.
    """
    if use_kernel_hook:
        from repro.kernels import dispatch
        fn = dispatch.get_attention()
        if fn is not None:
            return fn(q, k, v, q_positions=q_positions,
                      kv_valid_len=kv_valid_len, window=window,
                      softcap=softcap)
    b, s, _, _ = q.shape
    t = k.shape[1]
    if s * t <= SCORE_CHUNK_ELEMS or s == 1:
        return _attend_core(q, k, v, q_positions=q_positions,
                            kv_valid_len=kv_valid_len, window=window,
                            softcap=softcap)
    cs = _chunk_len(s, t)
    n = s // cs
    qc = jnp.moveaxis(q.reshape(b, n, cs, *q.shape[2:]), 1, 0)
    pc = jnp.moveaxis(q_positions.reshape(b, n, cs), 1, 0)

    def one(args):
        qi, pi = args
        return _attend_core(qi, k, v, q_positions=pi,
                            kv_valid_len=kv_valid_len, window=window,
                            softcap=softcap)

    if ANALYSIS_UNROLL:
        out = jnp.stack([one((qc[i], pc[i])) for i in range(n)])
    else:
        out = jax.lax.map(one, (qc, pc))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, *q.shape[2:])


def attention(params: dict, x: jax.Array, *, cfg: ModelConfig,
              positions: jax.Array, cache: dict | None = None,
              cache_index: jax.Array | None = None,
              page_table: jax.Array | None = None,
              ) -> tuple[jax.Array, dict | None]:
    """Self-attention with optional KV cache.

    cache: {"k": (B, Tmax, K, D), "v": ...}; cache_index: absolute position
    of the first new token (0 for prefill-from-empty) — a scalar int32, or
    a (B,) int32 vector when batch rows sit at different positions
    (continuous batching: each serving slot decodes at its own position
    with its own kv-valid horizon).  Returns (y, updated_cache).

    With ``page_table`` (B, pages_per_slot) the cache leaves are physical
    page pools ``(n_pages + 1, page_size, K, D)``: the new token's KV is
    scattered into its slot's page at ``cache_index``, and attention reads
    through the table (a scalar-prefetched Pallas kernel when a paged
    kernel is dispatched, a pool gather on the XLA reference path).
    Decode-only — prefill accumulates into dense row caches, which the
    serving engine scatters into pages at admission.
    """
    b, s, m = x.shape
    q = jnp.einsum("bsm,mhd->bshd", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsm,mkd->bskd", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsm,mkd->bskd", x, params["wv"].astype(x.dtype))
    mrope = cfg.mrope_sections if cfg.pos_embed == "mrope" else None
    if cfg.pos_embed in ("rope", "mrope"):
        q = apply_rope(q, positions, cfg.rope_theta, mrope)
        k = apply_rope(k, positions, cfg.rope_theta, mrope)
    qpos = positions[-1] if positions.ndim == 3 else positions  # t-axis for mrope
    if cache is None:
        y = attend(q, k, v, q_positions=qpos, kv_valid_len=s,
                   window=cfg.sliding_window)
        new_cache = None
    elif page_table is not None:
        idx = jnp.broadcast_to(
            jnp.asarray(cache_index, jnp.int32).reshape(-1), (b,))
        ps_sz = cache["k"].shape[1]
        if s == 1:
            bidx = jnp.arange(b, dtype=jnp.int32)
            phys = page_table[bidx, idx // ps_sz]   # (B,) physical page
            off = idx % ps_sz
            ck = cache["k"].at[phys, off].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[phys, off].set(
                v[:, 0].astype(cache["v"].dtype))
        else:
            # multi-token (speculative verify): scatter each row's S new
            # tokens through the table.  Unmapped spans point at the trash
            # page, so over-draft writes land harmlessly there.
            rows = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
            phys = page_table[bidx, rows // ps_sz]  # (B,S)
            off = rows % ps_sz
            ck = cache["k"].at[phys, off].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[phys, off].set(v.astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        from repro.kernels import dispatch
        fn = dispatch.get_paged_attention() if s == 1 else None
        if fn is not None:
            y = fn(q, ck, cv, page_table=page_table, q_positions=qpos,
                   kv_valid_len=idx + 1, window=cfg.sliding_window,
                   softcap=None)
        else:
            n_slot = page_table.shape[1]
            kd = ck[page_table].reshape(b, n_slot * ps_sz, *ck.shape[2:])
            vd = cv[page_table].reshape(b, n_slot * ps_sz, *cv.shape[2:])
            y = attend(q, kd, vd, q_positions=qpos, kv_valid_len=idx + s,
                       window=cfg.sliding_window, use_kernel_hook=False)
    else:
        idx = jnp.asarray(cache_index, jnp.int32)
        if idx.ndim:
            # per-row positions: scatter each row's new tokens at its own
            # index; kv-valid horizon is per-row too
            rows = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
            ck = cache["k"].at[bidx, rows].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, rows].set(v.astype(cache["v"].dtype))
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        y = attend(q, ck, cv, q_positions=qpos, kv_valid_len=idx + s,
                   window=cfg.sliding_window)
        new_cache = {"k": ck, "v": cv}
    y = jnp.einsum("bshd,hdm->bsm", y, params["wo"].astype(x.dtype))
    return y, new_cache


# --------------------------------------------------------------------------
# MLPs: swiglu / geglu (gated) and plain gelu
# --------------------------------------------------------------------------
def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    m, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((m, f), axes=("embed", "mlp")),
            "w_up": ParamSpec((m, f), axes=("embed", "mlp")),
            "w_down": ParamSpec((f, m), axes=("mlp", "embed")),
        }
    return {
        "w_up": ParamSpec((m, f), axes=("embed", "mlp")),
        "b_up": ParamSpec((f,), jnp.float32, ("mlp",), init="zeros"),
        "w_down": ParamSpec((f, m), axes=("mlp", "embed")),
        "b_down": ParamSpec((m,), jnp.float32, ("embed",), init="zeros"),
    }


def apply_mlp(params: dict, x: jax.Array, activation: str) -> jax.Array:
    from repro.kernels import dispatch
    mm = dispatch.get_matmul()
    if activation in ("swiglu", "geglu"):
        gate = mm(x, params["w_gate"].astype(x.dtype))
        up = mm(x, params["w_up"].astype(x.dtype))
        act = jax.nn.silu if activation == "swiglu" else (
            lambda a: jax.nn.gelu(a, approximate=True))
        h = act(gate.astype(jnp.float32)).astype(x.dtype) * up
        return mm(h, params["w_down"].astype(x.dtype))
    h = mm(x, params["w_up"].astype(x.dtype))
    h = h + params["b_up"].astype(h.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    out = mm(h, params["w_down"].astype(x.dtype))
    return out + params["b_down"].astype(out.dtype)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------
def embed_specs(cfg: ModelConfig) -> dict:
    s = {"embedding": ParamSpec((cfg.vocab_size, cfg.d_model),
                                axes=("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        s["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                 axes=("embed", "vocab"))
    return s


def embed(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(params["embedding"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    from repro.dist.sharding import hint
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsm,vm->bsv", x, params["embedding"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsm,mv->bsv", x, params["unembed"].astype(x.dtype))
    logits = hint(logits.astype(jnp.float32), ("batch", "seq", "vocab"))
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean CE over masked tokens. logits fp32 (B,S,V); labels (B,S) int32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        maskf = mask.astype(jnp.float32)
        return jnp.sum(nll * maskf) / jnp.maximum(jnp.sum(maskf), 1.0)
    return jnp.mean(nll)
