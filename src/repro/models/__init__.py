from repro.models.model import Model, build_model
from repro.models.params import (ParamSpec, abstract_params, init_params,
                                 param_bytes, param_count)
from repro.models.frontends import (abstract_inputs, input_specs,
                                    make_sample_inputs)

__all__ = [
    "Model", "build_model", "ParamSpec", "abstract_params", "init_params",
    "param_bytes", "param_count", "abstract_inputs", "input_specs",
    "make_sample_inputs",
]
