"""RG-LRU recurrent block + local-attention cache (RecurrentGemma / Griffin).

Recurrent block:
    y_branch = gelu(x W_y)
    u        = conv1d(x W_x)                      (causal depthwise, width 4)
    r_t      = sigmoid(BlockDiag_a(u_t));  i_t = sigmoid(BlockDiag_x(u_t))
    log a_t  = -c * r_t * softplus(Lambda)        (c = 8)
    h_t      = exp(log a_t) h_{t-1} + sqrt(1 - a_t^2) (i_t * u_t)
    out      = (h * y_branch) W_out

The linear recurrence runs as a parallel associative scan (fp32).  Gates use
block-diagonal linears with num_heads blocks, as in the DeepMind reference.

The attention layers of the hybrid use a *ring-buffer* window cache: slot
``pos % window`` holds token ``pos``; per-slot absolute positions make the
mask exact, so decode state stays O(window) — this is what makes
recurrentgemma runnable at the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RGLRUConfig
from repro.models.params import ParamSpec
from repro.models.ssm import causal_conv1d

C_GATE = 8.0


def rglru_specs(cfg: ModelConfig, rg: RGLRUConfig) -> dict:
    m, w = cfg.d_model, rg.lru_width
    nb = max(cfg.num_heads, 1)
    bw = w // nb
    return {
        "w_x": ParamSpec((m, w), axes=("embed", "inner")),
        "w_y": ParamSpec((m, w), axes=("embed", "inner")),
        "conv_w": ParamSpec((rg.conv_width, w), jnp.float32, ("conv", "inner")),
        "conv_b": ParamSpec((w,), jnp.float32, ("inner",), init="zeros"),
        "gate_a_w": ParamSpec((nb, bw, bw), jnp.float32,
                              ("heads", None, None)),
        "gate_a_b": ParamSpec((nb, bw), jnp.float32, ("heads", None),
                              init="zeros"),
        "gate_x_w": ParamSpec((nb, bw, bw), jnp.float32,
                              ("heads", None, None)),
        "gate_x_b": ParamSpec((nb, bw), jnp.float32, ("heads", None),
                              init="zeros"),
        "lam": ParamSpec((w,), jnp.float32, ("inner",), init="normal",
                         init_scale=0.8),
        "w_out": ParamSpec((w, m), axes=("inner", "embed")),
    }


def _block_diag(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """u (B,S,W) through block-diagonal linear w (NB,BW,BW) + b (NB,BW)."""
    bsz, s, width = u.shape
    nb, bw, _ = w.shape
    ub = u.reshape(bsz, s, nb, bw).astype(jnp.float32)
    out = jnp.einsum("bsnw,nwv->bsnv", ub, w) + b
    return out.reshape(bsz, s, width)


def _lru_scan(log_a: jax.Array, gated_x: jax.Array,
              h0: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """h_t = exp(log_a_t) h_{t-1} + gated_x_t via associative scan (fp32).

    log_a, gated_x: (B,S,W).  h0: (B,W) or None.  -> (h (B,S,W), h_last)."""
    if h0 is not None:
        # fold the initial state in as a virtual step 0
        log_a = jnp.concatenate(
            [jnp.zeros_like(log_a[:, :1]), log_a], axis=1)
        gated_x = jnp.concatenate(
            [h0.astype(gated_x.dtype)[:, None], gated_x], axis=1)

    def combine(left, right):
        la, lb = left
        ra, rb = right
        return la + ra, jnp.exp(ra) * lb + rb

    a_acc, h = jax.lax.associative_scan(combine, (log_a, gated_x), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h, h[:, -1]


def rglru_block(params: dict, x: jax.Array, *, cfg: ModelConfig,
                cache: dict | None = None,
                valid_len: jax.Array | None = None,
                ) -> tuple[jax.Array, dict | None]:
    """Full Griffin recurrent block.

    cache = {"h": (B,W) fp32, "conv": (B,conv_width-1,W)}.

    ``valid_len`` (traced scalar, or (B,) vector for per-row validity —
    used by the speculative verify restore pass): chunked-prefill padding
    support — for tokens past ``valid_len`` the recurrence is forced to
    the identity (log a = 0, gated input = 0), so h carries the last
    *real* token's state bit-exactly, and the conv state stops at that
    token too."""
    rg = cfg.rglru
    y_branch = jnp.einsum("bsm,mw->bsw", x, params["w_y"].astype(x.dtype))
    y_branch = jax.nn.gelu(y_branch.astype(jnp.float32),
                           approximate=True).astype(x.dtype)
    u = jnp.einsum("bsm,mw->bsw", x, params["w_x"].astype(x.dtype))
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = causal_conv1d(u, params["conv_w"], params["conv_b"],
                                conv_state,
                                valid_len=(valid_len if cache is not None
                                           else None))

    r = jax.nn.sigmoid(_block_diag(u, params["gate_a_w"], params["gate_a_b"]))
    i = jax.nn.sigmoid(_block_diag(u, params["gate_x_w"], params["gate_x_b"]))
    log_a = -C_GATE * r * jax.nn.softplus(params["lam"])      # (B,S,W) fp32
    a_sq = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a_sq, 1e-12)) * (
        i * u.astype(jnp.float32))
    if valid_len is not None:
        vl = jnp.asarray(valid_len, jnp.int32)
        offs = jnp.arange(x.shape[1], dtype=jnp.int32)
        live = ((offs[None, :] < vl[:, None]) if vl.ndim
                else (offs < vl)[None, :])[:, :, None]
        log_a = jnp.where(live, log_a, 0.0)
        gated = jnp.where(live, gated, 0.0)

    h0 = cache["h"] if cache is not None else None
    if cache is not None and x.shape[1] == 1:
        h_new = (jnp.exp(log_a[:, 0]) * h0.astype(jnp.float32)
                 + gated[:, 0])
        h = h_new[:, None]
        h_last = h_new
    else:
        h, h_last = _lru_scan(log_a, gated, h0)
    out = h.astype(x.dtype) * y_branch
    out = jnp.einsum("bsw,wm->bsm", out, params["w_out"].astype(x.dtype))
    new_cache = ({"h": h_last, "conv": new_conv}
                 if cache is not None else None)
    return out, new_cache


# --------------------------------------------------------------------------
# Ring-buffer window cache for the hybrid's local-attention layers
# --------------------------------------------------------------------------
def window_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    rg = cfg.rglru
    w = rg.window_size
    return {
        "k": ParamSpec((batch, w, cfg.num_kv_heads, cfg.head_dim),
                       jnp.bfloat16, ("batch", "window", "kv_heads",
                                      "head_dim"), init="zeros"),
        "v": ParamSpec((batch, w, cfg.num_kv_heads, cfg.head_dim),
                       jnp.bfloat16, ("batch", "window", "kv_heads",
                                      "head_dim"), init="zeros"),
        "pos": ParamSpec((batch, w), jnp.int32, ("batch", "window"),
                         init="zeros"),
    }


def init_window_cache(cfg: ModelConfig, batch: int) -> dict:
    from repro.models.params import init_params
    import jax.random as jr
    cache = init_params(jr.PRNGKey(0), window_cache_specs(cfg, batch))
    cache["pos"] = jnp.full_like(cache["pos"], -1)   # invalid slots
    return cache


def window_attention_decode(q: jax.Array, cache: dict, k_new: jax.Array,
                            v_new: jax.Array, t: jax.Array,
                            window: int) -> tuple[jax.Array, dict]:
    """One-token attention against a ring-buffer cache.

    q (B,1,H,D); k_new/v_new (B,1,K,D); t: absolute position — scalar
    int32 or (B,) vector when rows decode at different positions.
    Returns (context (B,1,H,D), new_cache)."""
    b, _, h, d = q.shape
    t = jnp.asarray(t, jnp.int32)
    slot = jnp.mod(t, window)
    if t.ndim:
        bidx = jnp.arange(b, dtype=jnp.int32)
        ck = cache["k"].at[bidx, slot].set(
            k_new[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, slot].set(
            v_new[:, 0].astype(cache["v"].dtype))
        cpos = cache["pos"].at[bidx, slot].set(t)
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.broadcast_to(t, (cache["pos"].shape[0], 1)
                                           ).astype(jnp.int32), (0, slot))
    kh = ck.shape[2]
    g = h // kh
    qf = q.reshape(b, 1, kh, g, d).astype(jnp.float32) * (d ** -0.5)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, ck.astype(jnp.float32))
    tcol = t[:, None] if t.ndim else t                         # (B,1) | ()
    valid = (cpos >= 0) & (cpos <= tcol) & (cpos > tcol - window)  # (B,Wnd)
    scores = jnp.where(valid[:, None, None, None, :], scores, -2.38e38)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, cv.astype(jnp.float32))
    ctx = ctx.reshape(b, 1, h, d).astype(q.dtype)
    return ctx, {"k": ck, "v": cv, "pos": cpos}


def window_attention_chunk(q: jax.Array, cache: dict, k_new: jax.Array,
                           v_new: jax.Array, t0: jax.Array,
                           valid_len: jax.Array,
                           window: int) -> tuple[jax.Array, dict]:
    """Chunked-prefill attention against the ring-buffer window cache.

    q (B,C,H,D): rotated queries at absolute positions t0..t0+C-1;
    k_new/v_new (B,C,K,D) the chunk's keys/values; ``t0``/``valid_len``
    are traced scalars, or (B,) vectors when rows sit at different
    positions / keep different numbers of real tokens (the speculative
    verify path) — only the first ``valid_len`` chunk tokens are real
    (the rest is bucket padding).  Queries attend both the ring cache
    (earlier chunks, per-slot absolute positions) and the in-chunk keys
    under the causal window mask; pad tokens are invisible as keys and
    are never written back, so padding can never evict a real in-window
    entry.  Returns (context (B,C,H,D), new_cache)."""
    b, c, h, d = q.shape
    t0 = jnp.asarray(t0, jnp.int32)
    vl = jnp.asarray(valid_len, jnp.int32)
    offs = jnp.arange(c, dtype=jnp.int32)
    per_row = bool(t0.ndim or vl.ndim)
    take = min(c, window)
    if per_row:
        t0 = jnp.broadcast_to(t0, (b,))
        vl = jnp.broadcast_to(vl, (b,))
        qpos = t0[:, None] + offs[None, :]                      # (B,C)
        chunk_pos = jnp.where(offs[None, :] < vl[:, None], qpos, -1)
        qpos_q = qpos[:, :, None]                               # (B,C,1)
    else:
        qpos = t0 + offs                                        # (C,)
        chunk_pos = jnp.broadcast_to(
            jnp.where(offs < vl, qpos, -1), (b, c))
        qpos_q = qpos[None, :, None]                            # (1,C,1)
    # one kv sequence: ring slots first (cache["pos"] holds absolute
    # positions, -1 = never written), then the chunk with pads masked out
    kv_pos = jnp.concatenate([cache["pos"], chunk_pos], axis=1)
    k_all = jnp.concatenate([cache["k"].astype(q.dtype), k_new], axis=1)
    v_all = jnp.concatenate([cache["v"].astype(q.dtype), v_new], axis=1)
    kh = k_all.shape[2]
    g = h // kh
    qf = q.reshape(b, c, kh, g, d).astype(jnp.float32) * (d ** -0.5)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, k_all.astype(jnp.float32))
    valid = ((kv_pos[:, None, :] >= 0)
             & (kv_pos[:, None, :] <= qpos_q)
             & (kv_pos[:, None, :] > qpos_q - window))
    scores = jnp.where(valid[:, None, None, :, :], scores, -2.38e38)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v_all.astype(jnp.float32))
    ctx = ctx.reshape(b, c, h, d).astype(q.dtype)

    # ring update: the last min(C, window) *real* tokens land at their
    # pos % window slots.  Pads are routed to a throwaway slot appended
    # past the ring (scatter drops it below), so they overwrite nothing.
    if per_row:
        start = jnp.clip(vl - take, 0, c - take)                # (B,)
        widx = start[:, None] + jnp.arange(take, dtype=jnp.int32)[None, :]
        wpos = t0[:, None] + widx                               # (B,take)
        slots = jnp.where(widx < vl[:, None], jnp.mod(wpos, window), window)
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]

        def put(buf, upd):
            padded = jnp.concatenate(
                [buf, jnp.zeros_like(buf[:, :1])], axis=1)
            return padded.at[bidx, slots].set(
                upd.astype(buf.dtype))[:, :window]

        ck = put(cache["k"], k_new[bidx, widx])
        cv = put(cache["v"], v_new[bidx, widx])
        cpos = put(cache["pos"][..., None], wpos[..., None])[..., 0]
        return ctx, {"k": ck, "v": cv, "pos": cpos}

    start = jnp.clip(vl - take, 0, c - take)
    widx = start + jnp.arange(take, dtype=jnp.int32)
    wpos = t0 + widx
    slots = jnp.where(widx < vl, jnp.mod(wpos, window), window)

    def put(buf, upd):
        padded = jnp.concatenate([buf, jnp.zeros_like(buf[:, :1])], axis=1)
        return padded.at[:, slots].set(upd.astype(buf.dtype))[:, :window]

    ck = put(cache["k"], jax.lax.dynamic_slice_in_dim(k_new, start, take, 1))
    cv = put(cache["v"], jax.lax.dynamic_slice_in_dim(v_new, start, take, 1))
    cpos = put(cache["pos"][..., None],
               jnp.broadcast_to(wpos[None, :, None], (b, take, 1)))[..., 0]
    return ctx, {"k": ck, "v": cv, "pos": cpos}


def fill_window_cache(cache: dict, k: jax.Array, v: jax.Array,
                      window: int) -> dict:
    """After prefill of S tokens, load the last min(S, window) into the ring
    buffer at their pos%window slots."""
    b, s = k.shape[0], k.shape[1]
    take = min(s, window)
    pos = jnp.arange(s - take, s, dtype=jnp.int32)             # absolute
    slots = jnp.mod(pos, window)
    ck = cache["k"].at[:, slots].set(k[:, -take:].astype(cache["k"].dtype))
    cv = cache["v"].at[:, slots].set(v[:, -take:].astype(cache["v"].dtype))
    cpos = cache["pos"].at[:, slots].set(
        jnp.broadcast_to(pos, (b, take)))
    return {"k": ck, "v": cv, "pos": cpos}
