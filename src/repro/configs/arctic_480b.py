"""Snowflake Arctic (base) — 480B MoE: 128 experts top-2 + dense residual.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000.  Arctic's signature is the *dense-MoE hybrid*: every
layer runs a small dense FFN residually in parallel with the 128-expert MoE.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,                      # dense residual branch width
    vocab_size=32000,
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        expert_d_ff=4864,
        dense_residual=True,
        capacity_factor=1.25,
    ),
    activation="swiglu",
    norm_type="rmsnorm",
    pos_embed="rope",
    rope_theta=10000.0,
)
