"""MiniCPM-2B — llama-like dense LM trained with the WSD schedule.

[arXiv:2404.06395; hf]  40L d_model=2304 36H (MHA kv=36) d_ff=5760
vocab=122753.  The WSD (warmup-stable-decay) schedule is implemented in
``repro.training.optimizer`` and used by the training example.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    activation="swiglu",
    norm_type="rmsnorm",
    pos_embed="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
)
