"""Qwen2-VL 2B — VLM backbone with M-RoPE and dynamic resolution.

[arXiv:2409.12191; hf]  28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936.  The vision frontend is a STUB per assignment: input_specs()
provides precomputed patch embeddings + 3D (t,h,w) M-RoPE position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    activation="swiglu",
    norm_type="rmsnorm",
    pos_embed="mrope",
    mrope_sections=(16, 24, 24),     # head_dim/2 = 64 = 16+24+24
    rope_theta=1000000.0,
    frontend="patch",
    tie_embeddings=True,
)
