"""DeepSeek-V2-Lite — 16B MoE with Multi-head Latent Attention.

[arXiv:2405.04434; hf]  27L d_model=2048 16H d_ff=1408(expert), vocab=102400.
MLA kv_lora_rank=512; 2 shared + 64 routed experts, top-6.  Layer 0 uses a
dense FFN (d_ff=10944) like the released checkpoint.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,                 # MLA: latent-shared; kept for bookkeeping
    head_dim=128,                    # v_head_dim
    d_ff=1408,                       # routed-expert intermediate
    vocab_size=102400,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        expert_d_ff=1408,
        num_shared_experts=2,
        shared_d_ff=2816,            # 2 shared experts fused: 2 x 1408
        capacity_factor=1.25,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        q_lora_rank=None,
    ),
    first_dense_layers=1,
    first_dense_d_ff=10944,
    activation="swiglu",
    norm_type="rmsnorm",
    pos_embed="rope",
    rope_theta=10000.0,
)
