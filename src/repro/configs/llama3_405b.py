"""Llama-3 405B — dense GQA LM, 128k vocab.

[arXiv:2407.21783; unverified]  126L d_model=16384 128H (GQA kv=8)
d_ff=53248 vocab=128256.  Training this on a single 256-chip v5e pod requires
Adafactor + bf16 grad accumulation + full remat + microbatching (see
EXPERIMENTS.md §Dry-run memory notes).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    activation="swiglu",
    norm_type="rmsnorm",
    pos_embed="rope",
    rope_theta=500000.0,
)
