"""The paper's evaluated workloads (MLPerf Server, Tbl. 2) as per-layer
GEMM-reduced profiles for the scheduler/compiler/simulator.

Convolutions are im2col'd: m = OH*OW (batch 1, the paper's serving regime),
k = Cin*KH*KW, n = Cout.  Depthwise convs: grouped — flops = HW*K2*C*2,
modelled as m=OH*OW, k=KH*KW, n=C with weight bytes C*K2.
QoS targets follow the paper's Tbl. 2 (ms).
"""
from __future__ import annotations

import dataclasses

from repro.core.cost_model import GemmLayer

IT = 4  # fp32 on the CPU platform


def conv(name, hw_in, cin, cout, k=3, stride=1) -> GemmLayer:
    hw_out = (hw_in + stride - 1) // stride
    return GemmLayer(name=name, m=hw_out * hw_out, k=cin * k * k, n=cout,
                     itemsize=IT, weight_bytes=cin * k * k * cout * IT)


def dwconv(name, hw_in, c, k=3, stride=1) -> GemmLayer:
    hw_out = (hw_in + stride - 1) // stride
    return GemmLayer(name=name, m=hw_out * hw_out, k=k * k, n=c,
                     itemsize=IT, weight_bytes=k * k * c * IT)


def fc(name, k, n) -> GemmLayer:
    return GemmLayer(name=name, m=1, k=k, n=n, itemsize=IT,
                     weight_bytes=k * n * IT)


def resnet50() -> list[GemmLayer]:
    ls = [conv("conv1", 224, 3, 64, k=7, stride=2)]
    spec = [(56, 64, 64, 256, 3), (28, 128, 128, 512, 4),
            (14, 256, 256, 1024, 6), (7, 512, 512, 2048, 3)]
    cin = 64
    for hw, c1, c3, cout, reps in spec:
        for r in range(reps):
            stride = 2 if (r == 0 and hw != 56) else 1
            hin = hw * stride
            ls.append(conv(f"res{hw}_{r}_a", hin, cin, c1, k=1,
                           stride=stride))
            ls.append(conv(f"res{hw}_{r}_b", hw, c1, c3, k=3))
            ls.append(conv(f"res{hw}_{r}_c", hw, c3, cout, k=1))
            if r == 0:
                ls.append(conv(f"res{hw}_{r}_sc", hin, cin, cout, k=1,
                               stride=stride))
            cin = cout
    ls.append(fc("fc", 2048, 1000))
    return ls


def googlenet() -> list[GemmLayer]:
    ls = [conv("conv1", 224, 3, 64, k=7, stride=2),
          conv("conv2a", 56, 64, 64, k=1),
          conv("conv2b", 56, 64, 192, k=3)]
    # inception modules: (hw, cin, [b1, b3r, b3, b5r, b5, pool_proj])
    modules = [
        (28, 192, (64, 96, 128, 16, 32, 32)),
        (28, 256, (128, 128, 192, 32, 96, 64)),
        (14, 480, (192, 96, 208, 16, 48, 64)),
        (14, 512, (160, 112, 224, 24, 64, 64)),
        (14, 512, (128, 128, 256, 24, 64, 64)),
        (14, 512, (112, 144, 288, 32, 64, 64)),
        (14, 528, (256, 160, 320, 32, 128, 128)),
        (7, 832, (256, 160, 320, 32, 128, 128)),
        (7, 832, (384, 192, 384, 48, 128, 128)),
    ]
    for i, (hw, cin, (b1, b3r, b3, b5r, b5, pp)) in enumerate(modules):
        ls.append(conv(f"inc{i}_1x1", hw, cin, b1, k=1))
        ls.append(conv(f"inc{i}_3r", hw, cin, b3r, k=1))
        ls.append(conv(f"inc{i}_3x3", hw, b3r, b3, k=3))
        ls.append(conv(f"inc{i}_5r", hw, cin, b5r, k=1))
        ls.append(conv(f"inc{i}_5x5", hw, b5r, b5, k=5))
        ls.append(conv(f"inc{i}_pp", hw, cin, pp, k=1))
    ls.append(fc("fc", 1024, 1000))
    return ls


def ssd_vgg() -> list[GemmLayer]:
    ls = []
    vgg = [(300, 3, 64), (300, 64, 64), (150, 64, 128), (150, 128, 128),
           (75, 128, 256), (75, 256, 256), (75, 256, 256), (38, 256, 512),
           (38, 512, 512), (38, 512, 512), (19, 512, 512), (19, 512, 512),
           (19, 512, 512)]
    for i, (hw, cin, cout) in enumerate(vgg):
        ls.append(conv(f"vgg{i}", hw, cin, cout, k=3))
    extras = [(19, 512, 1024, 3), (19, 1024, 1024, 1), (19, 1024, 256, 1),
              (10, 256, 512, 3), (10, 512, 128, 1), (5, 128, 256, 3),
              (5, 256, 128, 1), (3, 128, 256, 3)]
    for i, (hw, cin, cout, k) in enumerate(extras):
        ls.append(conv(f"extra{i}", hw, cin, cout, k=k))
    heads = [(38, 512), (19, 1024), (10, 512), (5, 256), (3, 256), (1, 256)]
    for i, (hw, cin) in enumerate(heads):
        ls.append(conv(f"head{i}", hw, cin, 6 * (4 + 81), k=3))
    return ls


def mobilenet_v2() -> list[GemmLayer]:
    ls = [conv("conv1", 224, 3, 32, k=3, stride=2)]
    # (t_expand, cout, reps, stride) per the paper
    blocks = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
              (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    hw, cin = 112, 32
    for bi, (t, cout, reps, stride) in enumerate(blocks):
        for r in range(reps):
            s = stride if r == 0 else 1
            ce = cin * t
            if t != 1:
                ls.append(conv(f"mb{bi}_{r}_e", hw, cin, ce, k=1))
            ls.append(dwconv(f"mb{bi}_{r}_d", hw, ce, k=3, stride=s))
            hw = (hw + s - 1) // s
            ls.append(conv(f"mb{bi}_{r}_p", hw, ce, cout, k=1))
            cin = cout
    ls.append(conv("conv_last", 7, 320, 1280, k=1))
    ls.append(fc("fc", 1280, 1000))
    return ls


def efficientnet_b0() -> list[GemmLayer]:
    ls = [conv("stem", 224, 3, 32, k=3, stride=2)]
    blocks = [(1, 16, 1, 1, 3), (6, 24, 2, 2, 3), (6, 40, 2, 2, 5),
              (6, 80, 3, 2, 3), (6, 112, 3, 1, 5), (6, 192, 4, 2, 5),
              (6, 320, 1, 1, 3)]
    hw, cin = 112, 32
    for bi, (t, cout, reps, stride, k) in enumerate(blocks):
        for r in range(reps):
            s = stride if r == 0 else 1
            ce = cin * t
            if t != 1:
                ls.append(conv(f"eff{bi}_{r}_e", hw, cin, ce, k=1))
            ls.append(dwconv(f"eff{bi}_{r}_d", hw, ce, k=k, stride=s))
            hw = (hw + s - 1) // s
            ls.append(conv(f"eff{bi}_{r}_p", hw, ce, cout, k=1))
            cin = cout
    ls.append(conv("head", 7, 320, 1280, k=1))
    ls.append(fc("fc", 1280, 1000))
    return ls


def tiny_yolov2() -> list[GemmLayer]:
    ls = []
    chans = [(416, 3, 16), (208, 16, 32), (104, 32, 64), (52, 64, 128),
             (26, 128, 256), (13, 256, 512), (13, 512, 1024),
             (13, 1024, 512)]
    for i, (hw, cin, cout) in enumerate(chans):
        ls.append(conv(f"conv{i}", hw, cin, cout, k=3))
    ls.append(conv("det", 13, 512, 425, k=1))
    return ls


def bert_large(seq: int = 128) -> list[GemmLayer]:
    """BERT-Large, MLPerf single-stream-ish seq 128 (seq 384 exceeds the
    64-core platform's roofline within the 130 ms QoS — the paper's served
    configuration must be the shorter-sequence one)."""
    d, f, layers = 1024, 4096, 24
    ls = []
    for i in range(layers):
        # qkv + attn-out + 2 ffn GEMMs aggregated into one effective GEMM
        flops = 2 * seq * d * (3 * d) + 2 * seq * d * d \
            + 2 * seq * seq * d * 2 + 2 * seq * d * f * 2
        n_eff = flops // (2 * seq * d)
        ls.append(GemmLayer(name=f"bert{i}", m=seq, k=d, n=int(n_eff),
                            itemsize=IT,
                            weight_bytes=(4 * d * d + 2 * d * f) * IT))
    return ls


@dataclasses.dataclass(frozen=True)
class PaperModel:
    name: str
    workload_class: str       # light | medium | heavy
    qos_ms: float
    layers: tuple


def paper_models() -> dict[str, PaperModel]:
    return {
        "resnet50": PaperModel("resnet50", "medium", 15.0,
                               tuple(resnet50())),
        "googlenet": PaperModel("googlenet", "medium", 15.0,
                                tuple(googlenet())),
        "efficientnet": PaperModel("efficientnet", "light", 10.0,
                                   tuple(efficientnet_b0())),
        "mobilenet_v2": PaperModel("mobilenet_v2", "light", 10.0,
                                   tuple(mobilenet_v2())),
        "ssd": PaperModel("ssd", "heavy", 100.0, tuple(ssd_vgg())),
        "tiny_yolov2": PaperModel("tiny_yolov2", "light", 10.0,
                                  tuple(tiny_yolov2())),
        "bert_large": PaperModel("bert_large", "heavy", 130.0,
                                 tuple(bert_large())),
    }


WORKLOAD_CLASSES = {
    "light": ("efficientnet", "mobilenet_v2", "tiny_yolov2"),
    "medium": ("resnet50", "googlenet"),
    "heavy": ("ssd", "bert_large"),
    "mix": ("resnet50", "googlenet", "efficientnet", "mobilenet_v2", "ssd",
            "tiny_yolov2", "bert_large"),
}
