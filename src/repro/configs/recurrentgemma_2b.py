"""RecurrentGemma-2B — RG-LRU + local attention hybrid (Griffin), 1:2 ratio.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000.  Block pattern (rec, rec, attn) x 8 + 2 trailing recurrent
layers.  Sub-quadratic: decode state is the RG-LRU hidden + a 2048-token
local-attention window, so this arch RUNS the long_500k cell.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    rglru=RGLRUConfig(
        lru_width=2560,
        conv_width=4,
        block_pattern=("rec", "rec", "attn"),
        window_size=2048,
        scan_chunk=256,
    ),
    activation="geglu",
    norm_type="rmsnorm",
    pos_embed="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
    embed_scale=True,
)
