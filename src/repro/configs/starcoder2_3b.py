"""StarCoder2-3B — dense code LM, GQA + RoPE, sliding window 4096.

[arXiv:2402.19173; hf]  30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152.  Non-gated gelu MLP with LayerNorm (starcoder2 style).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    activation="gelu",
    norm_type="layernorm",
    pos_embed="rope",
    rope_theta=999999.4,
    sliding_window=4096,
    tie_embeddings=True,
)
