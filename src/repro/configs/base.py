"""Unified model/run configuration.

One ``ModelConfig`` dataclass covers all 10 assigned architecture families
(dense / MoE / MLA / SSM / RG-LRU hybrid / VLM / audio).  Family-specific
sub-configs are ``None`` when unused.  ``ShapeConfig`` encodes the assigned
input-shape cells (train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # arctic: dense FFN residual branch running in parallel with the MoE branch
    dense_residual: bool = False
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int | None = None   # v2-lite: no q compression
    # decode-time matrix absorption (W_uk folded into q, W_uv into W_o).
    # Beyond-paper optimization; see EXPERIMENTS.md §Perf.
    absorb: bool = False


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block config."""
    d_inner: int = 3072
    head_dim: int = 64           # SSD head dim (P)
    state_dim: int = 128         # N
    num_groups: int = 1          # B/C groups
    conv_width: int = 4
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma recurrent-block config."""
    lru_width: int = 2560
    conv_width: int = 4
    block_pattern: tuple[str, ...] = ("rec", "rec", "attn")  # repeating
    window_size: int = 2048      # local attention window
    scan_chunk: int = 256        # chunked linear-scan granularity


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None

    activation: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm_type: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    pos_embed: Literal["rope", "mrope", "sinusoidal", "none"] = "rope"
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w pairs (qwen2-vl)
    sliding_window: int | None = None    # starcoder2 uses 4096
    tie_embeddings: bool = False
    embed_scale: bool = False            # gemma: x *= sqrt(d_model)
    logit_softcap: float | None = None
    # deepseek-v2: first k layers use a dense FFN instead of MoE
    first_dense_layers: int = 0
    first_dense_d_ff: int = 0
    # modality frontend stub: model consumes precomputed embeddings
    frontend: Literal["none", "patch", "frames"] = "none"
    dtype: str = "bfloat16"

    # -- derived -----------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state is O(1)/O(window) in sequence length."""
        return self.family in ("ssm", "hybrid")

    def cache_dtype(self):
        import jax.numpy as jnp
        return jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]
    # training only
    microbatch: int | None = None       # grad-accum microbatch (global); None = no accum
    remat: Literal["none", "full", "dots"] = "full"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, mode="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, mode="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128, mode="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1, mode="decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) cell is runnable; reason if not.

    long_500k needs sub-quadratic attention (DESIGN.md §4): only SSM/hybrid
    archs keep O(1)/O(window) decode state at 524k context.
    """
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, ("full-attention arch: 524k-token dense KV decode is "
                       "skipped per assignment (sub-quadratic archs only)")
    return True, ""
