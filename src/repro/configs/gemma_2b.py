"""Gemma-2B — dense LM with GeGLU, head_dim=256, MQA (kv=1).

[arXiv:2403.08295; hf]  18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=256000.  Embeddings are tied and scaled by sqrt(d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    norm_type="rmsnorm",
    pos_embed="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
    embed_scale=True,
)
