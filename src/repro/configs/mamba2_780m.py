"""Mamba-2 780M — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]  48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128.  d_inner = 2*d_model = 3072, head_dim 64 => 48 SSD heads.
Sub-quadratic: decode state is (heads, head_dim, state) per layer, so this
arch RUNS the long_500k cell.  The chunked SSD scan has a Pallas kernel
(repro.kernels.ssd_scan).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(
        d_inner=3072,
        head_dim=64,
        state_dim=128,
        num_groups=1,
        conv_width=4,
        chunk_size=256,
    ),
    norm_type="rmsnorm",
    pos_embed="none",
    tie_embeddings=True,
)
