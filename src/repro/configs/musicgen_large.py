"""MusicGen-Large — decoder-only LM over EnCodec audio tokens.

[arXiv:2306.05284; hf]  48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048.  EnCodec frontend is a STUB: input_specs() provides precomputed
frame embeddings (sum of codebook embeddings after the delay pattern).
Standard (non-gated) transformer: gelu MLP, layernorm, sinusoidal positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    activation="gelu",
    norm_type="layernorm",
    pos_embed="sinusoidal",
    frontend="frames",
)
