"""Architecture config registry.

``get_config("arctic-480b")`` returns the full assigned config;
``get_reduced_config(name)`` returns a same-family reduced config for CPU
smoke tests (few layers, narrow widths, few experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                RGLRUConfig, ShapeConfig, SHAPES, SSMConfig,
                                shape_applicable)

from repro.configs import (arctic_480b, deepseek_v2_lite_16b, gemma_2b,
                           llama3_405b, mamba2_780m, minicpm_2b,
                           musicgen_large, qwen2_vl_2b, recurrentgemma_2b,
                           starcoder2_3b)

_REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (arctic_480b, deepseek_v2_lite_16b, qwen2_vl_2b, musicgen_large,
              minicpm_2b, gemma_2b, llama3_405b, starcoder2_3b,
              recurrentgemma_2b, mamba2_780m)
}

ARCH_NAMES = tuple(sorted(_REGISTRY))


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return _REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {tuple(SHAPES)}")
    return SHAPES[name]


def all_cells(include_skipped: bool = False):
    """Yield (arch, shape, runnable, reason) for all 40 assigned cells."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, reason = shape_applicable(cfg, shape)
            if ok or include_skipped:
                yield arch, sname, ok, reason


def get_reduced_config(name: str) -> ModelConfig:
    """Same-family tiny config: one scan group, narrow dims, tiny vocab."""
    cfg = get_config(name)
    kw: dict = dict(
        name=cfg.name + "-reduced",
        num_layers=min(cfg.num_layers, 3),
        d_model=128,
        vocab_size=256,
    )
    if cfg.family != "ssm":
        kw.update(num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 2) or 1,
                  head_dim=32, d_ff=256)
    if cfg.pos_embed == "mrope":
        kw["mrope_sections"] = (4, 6, 6)   # half of reduced head_dim = 16
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=64,
            shared_d_ff=64 if cfg.moe.num_shared_experts else 0)
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32)
        kw["head_dim"] = 32
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_inner=256, head_dim=32, state_dim=16, chunk_size=16)
    if cfg.rglru is not None:
        kw["rglru"] = dataclasses.replace(
            cfg.rglru, lru_width=128, window_size=32, scan_chunk=16)
        kw["num_layers"] = 3   # one (rec, rec, attn) group
    if cfg.first_dense_layers:
        kw["first_dense_d_ff"] = 128
    if cfg.sliding_window:
        kw["sliding_window"] = 32
    return dataclasses.replace(cfg, **kw)


__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RGLRUConfig",
    "ShapeConfig", "SHAPES", "ARCH_NAMES", "get_config", "get_shape",
    "get_reduced_config", "all_cells", "shape_applicable",
]
