"""Adaptive-compilation demo: the single-pass multi-version compiler.

    PYTHONPATH=src python examples/adaptive_compilation_demo.py

Enumerates the schedule space for the paper's exemplary conv layer,
extracts the parallelism-locality Pareto frontier (Alg. 1), and shows how
the selected version flips as the interference level rises — including the
kernel-tile override the TPU serving path would install.
"""
from repro.configs.paper_suite import conv
from repro.core import cost_model as cm
from repro.core import schedule_space as ss
from repro.core.multiversion import compile_layer, extract_dominant
from repro.kernels import dispatch


def main():
    hw = cm.CPU_3990X
    layer = conv("resnet_14x14_256", 14, 256, 256, k=3)
    candidates = ss.enumerate_versions(layer, hw)
    frontier = extract_dominant(candidates)
    print(f"layer {layer.name}: {len(candidates)} candidates, "
          f"{len(frontier)} on the parallelism-locality frontier")

    vset = compile_layer(layer, hw, qos_budget_s=1e-3)
    print(f"retained {len(vset.versions)} versions "
          f"(paper: <=5, >80% of layers need <=3):")
    for i, v in enumerate(vset.versions):
        print(f"  v{i}: tile=({v.bm},{v.bk},{v.bn}) unroll={v.unroll} "
              f"parallelism={v.parallelism} "
              f"tile_bytes={v.tile_bytes/1e3:.0f}KB")

    print("\nselection vs interference level (16 cores):")
    for lvl in (0.0, 0.4, 0.7, 0.9, 1.0):
        itf = cm.Interference.from_level(lvl)
        v = vset.select(itf)
        lat = cm.latency(hw, v, 16, itf)
        print(f"  level={lvl:.1f} -> tile=({v.bm},{v.bk},{v.bn}) "
              f"lat={lat*1e6:.0f}us")
        # this is the hook the TPU serving engine uses: install the
        # selected version's tile as the Pallas kernel override — the
        # whole-table installer swaps atomically, so a concurrent trace
        # never observes a half-updated override table
        dispatch.install_tile_overrides(
            {"matmul": {"bm": min(v.bm, 256), "bk": min(v.bk, 512),
                        "bn": min(v.bn, 256)}})
    dispatch.clear_tile_overrides()


if __name__ == "__main__":
    main()
