"""Flagship example: multi-tenant serving with VELTAIR vs baselines.

    PYTHONPATH=src python examples/multi_tenant_serving.py \
        [--no-online] [--no-colocate]

Part 1 (simulator): compiles multi-version plans for the paper's MLPerf
mix, then serves a Poisson query stream under every scheduling policy and
prints the QoS table (Fig. 12-style).  All scheduling decisions run the
production repro.core code; time advancement is simulated.

Part 2 (online runtime): replays one tenant mix through the *real* JAX
ServingEngine with the VELTAIR policy in the loop — every engine step the
runtime polls the synthesized performance counters and the policy's
proxy maps them to the interference level that swaps the active kernel
code version (tile overrides via repro.kernels.dispatch) — and prints
the engine-vs-simulator ServingMetrics side by side.

Part 3 (co-location cluster): three *different* real models share the
unit pool under one global scheduler; see colocation_demo below for the
step-by-step walkthrough.

Part 4 (speculative decode): the same engine serving the same prompts
twice — plain fused quanta vs draft -> batched-verify -> rollback — and
asserting the streams are token-identical while speculation emits
multiple tokens per dispatch.
"""
import argparse
import time

from repro.configs.paper_suite import WORKLOAD_CLASSES, paper_models
from repro.core import cost_model as cm
from repro.core.qos import compare_metrics
from repro.core.scheduler import (LayerWisePolicy, ModelWisePolicy,
                                  PremaPolicy, VeltairPolicy)
from repro.serving import Simulator, build_paper_plans, poisson_workload


def sim_policy_table(hw, plans, models, weights):
    policies = [
        ("model-wise FCFS", lambda: ModelWisePolicy(hw)),
        ("layer-wise (Planaria-ported)", lambda: LayerWisePolicy(hw)),
        ("PREMA (temporal)", lambda: PremaPolicy(hw)),
        ("VELTAIR-AS", lambda: VeltairPolicy(hw, adaptive_compile=False)),
        ("VELTAIR-AC", lambda: VeltairPolicy(hw, adaptive_schedule=False)),
        ("VELTAIR-FULL", lambda: VeltairPolicy(hw)),
    ]
    print(f"\n{'policy':32s} " + " ".join(f"qps={q:<5d}" for q in (60, 140,
                                                                   220)))
    for name, pf in policies:
        rates = []
        for qps in (60, 140, 220):
            wl = poisson_workload(models, qps, 400, seed=1, weights=weights)
            m = Simulator(hw, plans, pf()).run(wl)
            rates.append(m.qos_rate)
        print(f"{name:32s} " + " ".join(f"{r:.2f}    " for r in rates))


def online_engine_demo(hw):
    """The real JAX engine under VeltairPolicy: one tenant mix replayed
    through simulator AND engine, metrics side by side."""
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import (OnlineRuntime, Workload,
                               engine_version_sets,
                               replay_through_simulator)
    from repro.serving.engine import ServingEngine

    tenants = ["resnet50", "googlenet"]
    plans = build_paper_plans(tenants, hw)
    policy = VeltairPolicy(hw)

    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=32,
                           version_sets=engine_version_sets(plans))

    # mixed-length prompts (spread > 0): slots decode at their own
    # positions, no alignment needed
    wl = Workload.poisson(tenants, 60, 24, prompt_len=4, max_new_tokens=4,
                          seed=1, prompt_len_spread=2)
    # AOT-compile every interference level's code version: each switch
    # during serve() is then a dictionary swap, never a re-jit stall
    engine.warmup(prompt_lens=tuple(sorted(set(wl.prompt_lengths()))))
    t0 = time.time()
    runtime = OnlineRuntime(engine, policy, plans, hw)
    m_eng = runtime.serve(wl)
    wall = time.time() - t0
    m_sim = replay_through_simulator(wl, hw, plans, VeltairPolicy(hw))

    lv = runtime.level_trace
    print(f"\nonline runtime: {m_eng.n_queries} queries through the real "
          f"engine in {wall:.1f}s wall ({runtime.steps} decode steps in "
          f"{runtime.quanta} fused dispatch quanta, "
          f"{engine.tokens_per_sync:.1f} tokens per host sync, "
          f"{engine.level_switches} version switches, "
          f"{1e3 * runtime.compile_time_s:.1f}ms in switches, "
          f"version cache {engine.version_cache.stats}, interference level "
          f"{min(lv):.2f}..{max(lv):.2f})")
    print(f"{'metric':18s} {'simulator':>12s} {'engine':>12s}")
    for field, (a, b) in compare_metrics(m_sim, m_eng).items():
        print(f"{field:18s} {a:12.4f} {b:12.4f}")


def colocation_demo(hw):
    """Co-location walkthrough: heterogeneous models, one unit pool.

    Each numbered step below is one knob of the co-location path; the
    printed block at the end is reproduced verbatim in README.md (keep
    them in sync)."""
    from repro.core.scheduler import ModelWisePolicy, PremaPolicy
    from repro.serving import ClusterRuntime, Workload, build_cluster, \
        cluster_plans

    # (1) Pick the tenants: three architectures from repro.configs with
    #     genuinely different layer profiles (dense attention, GQA code
    #     model, SSM).  Each gets an analytic ModelPlan on this hardware
    #     with a feasible auto-derived QoS (qos_scale x solo latency).
    archs = ["gemma-2b", "starcoder2-3b", "mamba2-780m"]
    plans = cluster_plans(archs, hw, qos_scale=3.0)

    # (2) Stand up one real (reduced) JAX engine per model.  Every engine
    #     owns its params, KV/SSM cache, and precompiled VersionCache;
    #     its tile table comes from its OWN plan's multi-version
    #     compilation, so per-engine levels select per-model code.
    tenants = build_cluster(archs, hw, batch_slots=2, max_len=32,
                            plans=plans)

    # (3) One shared Poisson stream whose tenant names route queries to
    #     the matching engine.
    wl = Workload.poisson(archs, 90, 18, prompt_len=4, max_new_tokens=3,
                          seed=1)

    # (4) Serve under the global scheduler.  Per quantum and per engine:
    #     counters are synthesized from the live slot occupancy of the
    #     co-resident engines, the calibrated LinearProxy maps them to a
    #     pressure estimate, plan_chunk_at forms the next layer-block
    #     (its size = the engine's dispatch quantum, its unit need = the
    #     engine's share of hw.n_units), and set_interference_level swaps
    #     that engine to the matching precompiled code version.
    print(f"\nco-locating {len(archs)} heterogeneous real engines on "
          f"{hw.n_units} {hw.unit}s ...")
    rows = []
    for name, policy in (("veltair", VeltairPolicy(hw)),
                         ("model-wise", ModelWisePolicy(hw)),
                         ("prema", PremaPolicy(hw))):
        runtime = ClusterRuntime(tenants if name == "veltair"
                                 else build_cluster(archs, hw, plans=plans),
                                 policy, hw)
        m = runtime.serve(wl)
        lv = "/".join(f"{m.mean_levels[a]:.2f}" for a in archs)
        rows.append((name, m.aggregate.qos_rate,
                     1e3 * m.aggregate.p99_latency_s,
                     sum(m.quanta.values()), m.pool_peak_used, lv))
    print(f"{'policy':12s} {'qos':>5s} {'p99_ms':>7s} {'quanta':>7s} "
          f"{'peak_units':>10s}  mean levels ({'/'.join(archs)})")
    for name, qos, p99, quanta, peak, lv in rows:
        print(f"{name:12s} {qos:5.2f} {p99:7.2f} {quanta:7d} {peak:10d}  "
              f"{lv}")


def speculative_demo():
    """Speculative decode quanta: the same prompts served twice through
    the same reduced model — plain fused quanta, then draft -> batched
    verify -> rollback — with the streams asserted token-identical."""
    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving.engine import Request, ServingEngine

    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # repetitive continuations (the serving analogue of templated text)
    # are where prompt-lookup drafts land; fresh random prompts would
    # still be token-identical but mostly fall back to plain quanta
    prompts = [np.full(n, 7 + n, np.int32) for n in (12, 9, 6)]

    def serve(speculative):
        eng = ServingEngine(cfg, params, batch_slots=2, max_len=160,
                            speculative=speculative)
        eng.warmup(prompt_lens=tuple(len(p) for p in prompts))
        reqs = [Request(rid=i, prompt=p, max_new_tokens=96)
                for i, p in enumerate(prompts)]
        pending = list(reqs)
        while pending and eng.admit_request(pending[0], drain=True):
            pending.pop(0)
        t0 = time.time()
        while pending or not all(r.done for r in reqs):
            eng.step_quantum(8)
            while pending and eng.admit_request(pending[0], drain=True):
                pending.pop(0)
        return eng, [list(r.output) for r in reqs], time.time() - t0

    _, plain, dt_p = serve(False)
    eng, spec, dt_s = serve(True)
    s = eng.spec_stats
    toks = sum(len(o) for o in spec)
    print(f"\nspeculative decode: {toks} tokens, token-identical="
          f"{plain == spec}, plain {toks/dt_p:.0f} tok/s -> spec "
          f"{toks/dt_s:.0f} tok/s ({s['spec_quanta']} spec quanta, "
          f"hit rate {s['draft_hit_rate']:.0%}, "
          f"{s['spec_rollbacks']} rollbacks, "
          f"{s['spec_fallbacks']} fallbacks)")
    assert plain == spec, "speculation must never change the tokens"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-online", action="store_true",
                    help="skip the real-engine replay (simulator only)")
    ap.add_argument("--no-colocate", action="store_true",
                    help="skip the multi-engine co-location demo")
    ap.add_argument("--no-spec", action="store_true",
                    help="skip the speculative-decode demo")
    args = ap.parse_args()

    hw = cm.CPU_3990X
    pm = paper_models()
    models = list(WORKLOAD_CLASSES["mix"])
    print(f"compiling multi-version plans for {len(models)} tenants ...")
    t0 = time.time()
    plans = build_paper_plans(models, hw)
    print(f"  done in {time.time()-t0:.1f}s; per-model versions: "
          + ", ".join(
          f"{n}={sum(len(v.versions) for v in p.version_sets)}"
          for n, p in plans.items()))

    weights = [1.0 / pm[m].qos_ms for m in models]
    sim_policy_table(hw, plans, models, weights)

    if not args.no_online:
        online_engine_demo(hw)

    if not args.no_colocate:
        colocation_demo(hw)

    if not args.no_spec:
        speculative_demo()


if __name__ == "__main__":
    main()
