"""Flagship example: multi-tenant serving with VELTAIR vs baselines.

    PYTHONPATH=src python examples/multi_tenant_serving.py

Compiles multi-version plans for the paper's MLPerf mix, then serves a
Poisson query stream under every scheduling policy and prints the QoS
table (Fig. 12-style).  All scheduling decisions run the production
repro.core code; time advancement is simulated (this container has one
CPU device — see DESIGN.md §2, measurement substrate).
"""
import time

from repro.configs.paper_suite import WORKLOAD_CLASSES, paper_models
from repro.core import cost_model as cm
from repro.core.scheduler import (LayerWisePolicy, ModelWisePolicy,
                                  PremaPolicy, VeltairPolicy)
from repro.serving import Simulator, build_paper_plans, poisson_workload


def main():
    hw = cm.CPU_3990X
    pm = paper_models()
    models = list(WORKLOAD_CLASSES["mix"])
    print(f"compiling multi-version plans for {len(models)} tenants ...")
    t0 = time.time()
    plans = build_paper_plans(models, hw)
    print(f"  done in {time.time()-t0:.1f}s; per-model versions: "
          + ", ".join(
          f"{n}={sum(len(v.versions) for v in p.version_sets)}"
          for n, p in plans.items()))

    weights = [1.0 / pm[m].qos_ms for m in models]
    policies = [
        ("model-wise FCFS", lambda: ModelWisePolicy(hw)),
        ("layer-wise (Planaria-ported)", lambda: LayerWisePolicy(hw)),
        ("PREMA (temporal)", lambda: PremaPolicy(hw)),
        ("VELTAIR-AS", lambda: VeltairPolicy(hw, adaptive_compile=False)),
        ("VELTAIR-AC", lambda: VeltairPolicy(hw, adaptive_schedule=False)),
        ("VELTAIR-FULL", lambda: VeltairPolicy(hw)),
    ]
    print(f"\n{'policy':32s} " + " ".join(f"qps={q:<5d}" for q in (60, 140,
                                                                   220)))
    for name, pf in policies:
        rates = []
        for qps in (60, 140, 220):
            wl = poisson_workload(models, qps, 400, seed=1, weights=weights)
            m = Simulator(hw, plans, pf()).run(wl)
            rates.append(m.qos_rate)
        print(f"{name:32s} " + " ".join(f"{r:.2f}    " for r in rates))


if __name__ == "__main__":
    main()
