"""TPU-pod multi-tenant serving: the paper's technique on the target HW.

    PYTHONPATH=src python examples/tpu_pod_serving.py

Here the shared resource is a 256-chip v5e pod (DESIGN.md §2): tenants are
assigned LM architectures at serving shapes, per-layer profiles come from
the real model configs (core/profiles.py), versions trade sharding degree
against HBM/ICI pressure, and VELTAIR's scheduler allocates *chips* per
layer-block.
"""
import time

from repro.core import cost_model as cm
from repro.core.scheduler import (LayerWisePolicy, ModelWisePolicy,
                                  VeltairPolicy)
from repro.serving import Simulator, lm_serving_plans, poisson_workload


def main():
    hw = cm.TPU_V5E_POD
    tenants = [
        ("gemma-2b", "decode_32k", 40.0),       # qos_ms per decode batch
        ("starcoder2-3b", "decode_32k", 60.0),
        ("mamba2-780m", "decode_32k", 25.0),
        ("deepseek-v2-lite-16b", "decode_32k", 120.0),
    ]
    print("compiling multi-version plans for LM tenants on the v5e pod ...")
    t0 = time.time()
    plans = lm_serving_plans(tenants)
    for name, p in plans.items():
        print(f"  {name:38s} layers={p.n_layers:3d} Avg_C={p.avg_units:3d}"
              f" chips, versions="
              f"{sum(len(v.versions) for v in p.version_sets)}")
    print(f"  ({time.time()-t0:.1f}s)")

    names = list(plans)
    weights = [1.0 / q for _, _, q in tenants]
    print(f"\n{'policy':22s} " + " ".join(f"qps={q:<5d}" for q in (20, 60,
                                                                   120)))
    for label, pf in [("model-wise", lambda: ModelWisePolicy(hw)),
                      ("layer-wise", lambda: LayerWisePolicy(hw)),
                      ("VELTAIR-FULL", lambda: VeltairPolicy(hw))]:
        rates = []
        for qps in (20, 60, 120):
            wl = poisson_workload(names, qps, 300, seed=0, weights=weights)
            m = Simulator(hw, plans, pf()).run(wl)
            rates.append(m.qos_rate)
        print(f"{label:22s} " + " ".join(f"{r:.2f}    " for r in rates))


if __name__ == "__main__":
    main()
