"""Quickstart: serve a small model with batched requests (end-to-end).

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced gemma-2b, admits a handful of prompts through the
continuous-batching engine, and greedily decodes — the serving path the
paper's system schedules at pod scale.  A second pass serves the same
prompts speculatively (draft -> batched verify -> rollback) and checks
the streams are token-identical.
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def make_requests(cfg, rng):
    return [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                max_new_tokens=12)
        for i in range(8)
    ]


def serve(engine, requests):
    """Admit with drain=True (queue + pump prefill until first token),
    then drain decode through fused quanta."""
    pending = list(requests)
    t0 = time.time()
    while pending and engine.admit_request(pending[0], drain=True):
        pending.pop(0)
    while pending or not all(r.done for r in requests):
        engine.step_quantum(engine.quantum_buckets[-1])
        while pending and engine.admit_request(pending[0], drain=True):
            pending.pop(0)
    return time.time() - t0


def main():
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_slots=4, max_len=48)

    rng = np.random.default_rng(0)
    requests = make_requests(cfg, rng)
    dt = serve(engine, requests)
    tokens = sum(len(r.output) for r in requests)
    print(f"served {len(requests)} requests, {tokens} tokens "
          f"in {dt:.2f}s ({tokens/dt:.1f} tok/s on CPU)")
    for r in requests[:3]:
        print(f"  req {r.rid}: prompt[:5]={r.prompt[:5].tolist()} "
              f"-> output={r.output}")

    # -- speculative decode: same tokens, fewer dispatches ---------------
    spec = ServingEngine(cfg, params, batch_slots=4, max_len=48,
                         speculative=True)
    spec_reqs = make_requests(cfg, np.random.default_rng(0))
    serve(spec, spec_reqs)
    identical = [r.output for r in spec_reqs] == [r.output for r in requests]
    s = spec.spec_stats
    print(f"speculative: token-identical={identical}, "
          f"{s['spec_quanta']} spec quanta, "
          f"{s['tokens_accepted']}/{s['tokens_drafted']} drafts accepted "
          f"(hit rate {s['draft_hit_rate']:.0%}, "
          f"{s['spec_rollbacks']} rollbacks)")
    assert identical, "speculation must never change the tokens"


if __name__ == "__main__":
    main()
