"""Quickstart: serve a small model with batched requests (end-to-end).

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced gemma-2b, admits a handful of prompts through the
continuous-batching engine, and greedily decodes — the serving path the
paper's system schedules at pod scale.
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_slots=4, max_len=48)

    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                max_new_tokens=12)
        for i in range(8)
    ]
    t0 = time.time()
    done = engine.run_to_completion(requests)
    dt = time.time() - t0
    tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {tokens} tokens "
          f"in {dt:.2f}s ({tokens/dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:5]={r.prompt[:5].tolist()} "
              f"-> output={r.output}")


if __name__ == "__main__":
    main()
