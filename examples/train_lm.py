"""Train a reduced LM for a few hundred steps (WSD schedule, checkpoints).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import tempfile

from repro.configs import get_reduced_config
from repro.data import DataConfig
from repro.models import build_model, param_count
from repro.training import OptimizerConfig, TrainConfig
from repro.training.train_loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="minicpm-2b")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    model = build_model(cfg)
    print(f"training {cfg.name}: {param_count(model.param_specs()):,} params")
    tc = TrainConfig(
        optimizer=OptimizerConfig(lr=2e-3, schedule="wsd",
                                  warmup_steps=args.steps // 10,
                                  total_steps=args.steps),
        accum_steps=2)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        out = train_loop(model, tc, dc,
                         LoopConfig(total_steps=args.steps,
                                    ckpt_dir=ckpt_dir, ckpt_every=50,
                                    log_every=20))
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
