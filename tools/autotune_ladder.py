"""Autotune an interference-level -> tile-table ladder (LadderSpec JSON).

Drives :func:`benchmarks.hillclimb.search_tile_ladder` over a
representative GEMM layer — by default the dominant-FLOPs layer of a
paper-suite model — and writes the resulting
:class:`repro.core.multiversion.LadderSpec` to JSON.  The artifact
replaces the engine's hand-written ``DEFAULT_LEVEL_TILES``:

    python tools/autotune_ladder.py --model resnet50 --out ladder.json
    # then, in the serving process:
    #   repro.kernels.dispatch.load_ladder("ladder.json")
    # or pass the spec to ServingEngine(ladder=...)

``--smoke`` tunes a small synthetic GEMM over a restricted tile set —
sub-second, exercised by the fast CI job as an end-to-end
search -> validate -> serialize check.  Exit code 0 means the emitted
spec round-trips and satisfies the ladder ordering invariant.
"""
import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(ROOT / "src"), str(ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import cost_model as cm                      # noqa: E402
from repro.core.multiversion import LadderSpec               # noqa: E402
from benchmarks.hillclimb import search_tile_ladder          # noqa: E402

SMOKE_TILES = (32, 64, 128, 256)


def representative_layer(model: str) -> cm.GemmLayer:
    """The dominant-FLOPs layer of a paper-suite model — the layer whose
    tiling the whole model's version choice is most sensitive to."""
    from repro.configs.paper_suite import paper_models
    pm = paper_models()[model]
    return max(pm.layers, key=lambda l: l.flops)


def smoke_layer() -> cm.GemmLayer:
    return cm.GemmLayer(name="smoke512", m=512, k=512, n=512, itemsize=4,
                        weight_bytes=512 * 512 * 4)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="resnet50",
                    help="paper-suite model supplying the representative "
                         "layer (ignored with --smoke)")
    ap.add_argument("--hw", default="cpu", choices=("cpu", "tpu"),
                    help="hardware model to tune against")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: ladder_<name>.json; "
                         "'-' prints to stdout)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny synthetic search (CI: fast end-to-end "
                         "search -> validate -> serialize check)")
    ap.add_argument("--units", type=int, default=None,
                    help="co-runner unit share to model (default n_units/4)")
    args = ap.parse_args(argv)

    hw = cm.CPU_3990X if args.hw == "cpu" else cm.TPU_V5E_POD
    if args.smoke:
        layer, tiles, label = smoke_layer(), SMOKE_TILES, "smoke"
    else:
        layer, tiles, label = representative_layer(args.model), None, \
            args.model

    kw = {"units": args.units, "name": f"{label}@{hw.name}"}
    if tiles is not None:
        kw["tiles"] = tiles
    spec = search_tile_ladder(layer, hw, **kw)

    # round-trip through the serialized form before declaring success —
    # the file is only useful if dispatch.load_ladder can consume it
    text = spec.to_json()
    back = LadderSpec.from_json(text)
    assert back.levels == spec.levels

    if args.out == "-":
        print(text)
        return 0
    out = pathlib.Path(args.out or f"ladder_{label}.json")
    out.write_text(text)
    distinct = len(spec.tile_tables())
    print(f"[autotune_ladder] {spec.name}: {len(spec)} levels "
          f"({distinct} distinct tables) -> {out}")
    print(f"[autotune_ladder] level latencies (us): "
          f"{[round(s * 1e6, 1) for s in spec.scores]}")
    print(json.dumps(spec.meta))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
