#!/usr/bin/env python
"""CI gate: static invariant checking over the serving hot path.

Runs the ``repro.analysis`` rule corpus (host-sync, donation, retrace,
paged-leaf, tile-atomicity, syntax) over the given paths and exits
nonzero on any active violation.  Suppress a finding in place with
``# veltair: ignore[rule-id] justification``.

Usage::

    python tools/check_static.py src                 # the CI gate
    python tools/check_static.py src examples tools  # wider sweep
    python tools/check_static.py --json src          # machine-readable
    python tools/check_static.py --rules syntax src  # a subset
    python tools/check_static.py --list-rules
"""
from __future__ import annotations

import argparse
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import all_rules, run  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="VELTAIR static invariant checker")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--json", action="store_true",
                    help="emit per-violation JSON records to stdout")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, rule in sorted(all_rules().items()):
            print(f"{rid:24s} {rule.description}")
        return 0

    paths = args.paths or [str(ROOT / "src")]
    missing = [p for p in paths if not pathlib.Path(p).exists()]
    if missing:
        print(f"check_static: FAIL: no such path: {', '.join(missing)}",
              file=sys.stderr)
        return 2
    rule_ids = ([r.strip() for r in args.rules.split(",") if r.strip()]
                if args.rules else None)
    try:
        report = run(paths, rule_ids)
    except KeyError as e:
        print(f"check_static: FAIL: {e.args[0]}", file=sys.stderr)
        return 2

    if args.json:
        print(report.to_json())
    else:
        for v in report.violations:
            print(v.format())
        for v in report.suppressed:
            if not v.justified:
                print(f"note: {v.format()} — suppression has no "
                      f"justification text")
        print(report.summary())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
