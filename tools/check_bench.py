"""Bench gate: the fused dispatch quantum must actually win, and
SLO-tiered scheduling must actually buy queries-under-QoS.

Reads BENCH_serving.json (written by ``python -m
benchmarks.bench_online_serving [--tiny]`` at the repo root) and fails
if the fused quantum path's warm decode throughput regressed below the
per-step dispatch loop (minus a noise tolerance — wall-clock on shared
runners is not deterministic), if fusion stopped coarsening the host
boundary (tokens per device->host sync back at ~1; strict — counted,
not timed), if the chunked prefill path retraced under mixed-length
traffic (strict), or if the ``slo`` section's headline metric slipped:
SLO-tiered EDF + admission control must serve >= SLO_GAIN_MIN x the
queries-under-QoS (``qps_at_qos``) of the FIFO baseline at equal
offered load, with strict tier ordering (interactive qos_rate >=
standard >= batch) and token-identical per-request outputs across the
two schedules — all three strict, because the slo serve runs in
deterministic virtual time.  The ``paged`` section gates the paged KV
cache the same way (also virtual-time exact): >= PAGED_GAIN_MIN x the
dense engine's peak concurrent requests at an equal device memory
budget, token-identical outputs, zero post-warmup retraces, a counted
shed/defer response to page-pool exhaustion, and >= 1 page deduplicated
by cross-request prefix sharing in the paged cluster.  The ``measured``
section gates the closed adaptive-compilation loop: the proxy's
sliding-window RMS residual while serving on measured per-quantum
wall-time counters must stay <= 1.5x the oracle-calibration residual,
the autotuned tile ladder must serve >= the fixed level table's
queries-under-QoS (virtual-time exact), and the ladder engine must hold
zero post-warmup retraces.  The ``spec`` section gates speculative
decode quanta: >= SPEC_GAIN_MIN x the plain fused path's wall-clock
tokens/s on the repetitive workload with token-identical streams and
zero post-warmup retraces, and >= SPEC_ADVERSARIAL_MIN x on the
adversarial low-hit-rate workload (drafting must be near-free when it
misses).  Run from the repo root:

    python -m benchmarks.bench_online_serving --tiny
    python tools/check_bench.py

Exit code 0 = every gate holds; 1 = regression (each failed check is
printed).
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT = ROOT / "BENCH_serving.json"

# Wall-clock throughput on shared CI runners is noisy even after
# best-of-N; requiring fused to STRICTLY beat per-step with zero margin
# flaked on correlated load spikes.  Fused must stay within this
# fraction of per-step (a real regression — fusion overhead eating the
# win — shows up far below it); the tokens-per-sync check stays strict
# because it is deterministic (counted, not timed).
THROUGHPUT_TOLERANCE = 0.10

# The slo section is virtual-time deterministic (no wall-clock noise),
# so its gates are exact.  The ISSUE-6 acceptance floor: SLO-tiered
# scheduling must serve at least this multiple of the FIFO baseline's
# queries-under-QoS on the bursty overload workload.
SLO_GAIN_MIN = 1.3
SLO_TIER_ORDER = ("interactive", "standard", "batch")

# The paged section also serves in virtual time, so its gates are exact.
# The ISSUE-7 acceptance floor: at an equal device memory budget the
# paged KV cache must sustain at least this multiple of the dense
# engine's peak concurrent requests, with token-identical outputs.
PAGED_GAIN_MIN = 1.5

# The measured section (ISSUE-8): serving on measured per-quantum
# wall-time counters with the online RLS re-fit must keep the proxy's
# sliding-window RMS residual within this multiple of the offline
# oracle-calibration residual, and the autotuned tile ladder must serve
# at least as many queries-under-QoS as the fixed level table (exact:
# virtual time) with zero post-warmup retraces.
MEASURED_ERR_MAX = 1.5

# The spec section (ISSUE-9): on the repetitive workload, speculative
# decode quanta must beat the plain fused path by this factor in warm
# wall-clock tokens/s (the arm is built to hold a comfortable margin —
# ~1.5x locally — so the gate survives CI noise), with token-identical
# streams and zero post-warmup retraces; on the adversarial low-hit-rate
# workload the draft+fallback overhead must not cost more than this
# fraction of plain throughput.
SPEC_GAIN_MIN = 1.3
SPEC_ADVERSARIAL_MIN = 0.95


RERUN = "rerun `python -m benchmarks.bench_online_serving --tiny`"


def load(path: pathlib.Path) -> dict | None:
    """Parse the bench JSON, or None (the caller already errored)."""
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return None


def check(path: pathlib.Path) -> list[str]:
    if not path.exists():
        return [f"{path.name} missing — run "
                "`python -m benchmarks.bench_online_serving --tiny` first"]
    data = load(path)
    if data is None or not isinstance(data, dict):
        return [f"{path.name} is not valid JSON — {RERUN}"]
    q = data.get("quantum")
    if not q or "fused" not in q or "per_step" not in q:
        return [f"{path} has no quantum section (stale file?)"]
    fused, per_step = q["fused"], q["per_step"]
    errors = []
    floor = (1.0 - THROUGHPUT_TOLERANCE) * per_step["tokens_per_s"]
    if not fused["tokens_per_s"] >= floor:
        errors.append(
            f"fused warm decode regressed below per-step dispatch: "
            f"{fused['tokens_per_s']} < {floor:.1f} tok/s "
            f"(per-step {per_step['tokens_per_s']} minus "
            f"{THROUGHPUT_TOLERANCE:.0%} noise tolerance)")
    # deterministic (load-independent) check: fusion must coarsen the host
    # boundary RELATIVE to the per-step baseline — batching/admissions
    # already put the per-step arm above 1 token/sync, so comparing
    # against a constant would miss fusion degenerating to 1-step quanta
    if not fused["tokens_per_sync"] > 1.5 * per_step["tokens_per_sync"]:
        errors.append(
            f"fused path is not coarsening the host boundary: "
            f"{fused['tokens_per_sync']} tokens/sync vs per-step's "
            f"{per_step['tokens_per_sync']} (expected > 1.5x)")
    if fused["tokens"] != per_step["tokens"]:
        errors.append(
            f"fused and per-step runs decoded different token counts "
            f"({fused['tokens']} vs {per_step['tokens']}) — the comparison "
            "is not apples-to-apples")
    # mixed-length admission path (deterministic): the chunked/bucketed
    # prefill must perform zero post-warmup retraces, and the monolithic
    # arm is the counterexample that keeps the comparison honest
    p = data.get("prefill")
    if p and "chunked" in p:
        if p["chunked"]["post_warmup_traces"] != 0:
            errors.append(
                f"chunked prefill retraced under mixed-length traffic: "
                f"{p['chunked']['post_warmup_traces']} post-warmup traces "
                "(bucket table must cover every admitted length)")
        if "monolithic" in p and \
                p["monolithic"]["post_warmup_traces"] == 0:
            errors.append(
                "monolithic prefill arm performed zero retraces on a "
                "mixed-length workload — the benchmark is not actually "
                "exercising the length spread")
    errors.extend(check_slo(data.get("slo")))
    errors.extend(check_paged(data.get("paged")))
    errors.extend(check_measured(data.get("measured")))
    errors.extend(check_spec(data.get("spec")))
    return errors


def check_spec(s: dict | None) -> list[str]:
    """The speculative-decode gates (ISSUE-9)."""
    if not s or "repetitive" not in s or "adversarial" not in s:
        return ["BENCH_serving.json has no spec section (stale file?) — "
                "rerun `python -m benchmarks.bench_online_serving --tiny`"]
    errors = []
    rep = s["repetitive"]
    if not rep["speedup_tokens_per_s"] >= SPEC_GAIN_MIN:
        errors.append(
            f"speculative decode lost its repetitive-workload win: "
            f"{rep['spec']['tokens_per_s']} tok/s vs plain fused's "
            f"{rep['plain']['tokens_per_s']} "
            f"(x{rep['speedup_tokens_per_s']}, need >= {SPEC_GAIN_MIN}x)")
    for wl_name in ("repetitive", "adversarial"):
        if not s[wl_name].get("token_identical", False):
            errors.append(
                f"speculative and plain engines produced different token "
                f"streams on the {wl_name} workload — draft/verify/"
                "rollback must change the schedule, never the tokens")
        if s[wl_name]["spec"]["post_warmup_traces"] != 0:
            errors.append(
                f"speculative engine retraced after warmup on the "
                f"{wl_name} workload: "
                f"{s[wl_name]['spec']['post_warmup_traces']} traces "
                "(warmup must prebuild every (K-bucket, depth) verify "
                "executable)")
    if rep["spec"].get("spec_quanta", 0) <= 0:
        errors.append(
            "the repetitive arm dispatched zero speculative quanta — the "
            "speedup comparison is vacuous (spec path never engaged)")
    adv = s["adversarial"]
    ratio = adv["spec"]["tokens_per_s"] \
        / max(adv["plain"]["tokens_per_s"], 1e-9)
    if not ratio >= SPEC_ADVERSARIAL_MIN:
        errors.append(
            f"speculation is no longer near-free when drafts miss: "
            f"adversarial arm at {ratio:.2f}x plain throughput "
            f"(need >= {SPEC_ADVERSARIAL_MIN}x — draft cost or fallback "
            "overhead crept into the serving path)")
    return errors


def check_measured(m: dict | None) -> list[str]:
    """The measured-counter / autotuned-ladder gates (ISSUE-8)."""
    if not m or "proxy" not in m or "ladder" not in m:
        return ["BENCH_serving.json has no measured section (stale "
                "file?) — rerun "
                "`python -m benchmarks.bench_online_serving --tiny`"]
    errors = []
    pr = m["proxy"]
    if not pr["measured_rms"] <= MEASURED_ERR_MAX * pr["oracle_rms"]:
        errors.append(
            f"measured-counter proxy error blew past calibration: "
            f"window rms {pr['measured_rms']} vs oracle-calibrated "
            f"{pr['oracle_rms']} (need <= {MEASURED_ERR_MAX}x — the "
            "online RLS re-fit is not tracking the measured pressure)")
    if pr.get("polls", {}).get("measured", 0) <= 0:
        errors.append(
            "the measured serve never polled a measured counter sample — "
            "the CounterBank stayed cold for the whole run and every "
            "sample fell back to the oracle synthesizer")
    if pr.get("rls_updates", 0) <= 0:
        errors.append(
            "the online proxy re-fit received zero observations during "
            "the measured serve — observe_counters is not being called")
    lad = m["ladder"]
    fixed_q = lad["fixed"]["qps_at_qos"]
    auto_q = lad["autotuned"]["qps_at_qos"]
    if not auto_q >= fixed_q:
        errors.append(
            f"autotuned ladder lost queries-under-QoS to the fixed level "
            f"table: {auto_q} vs {fixed_q} qps_at_qos (virtual time — "
            "the comparison is exact, this is a real regression)")
    if lad["autotuned"]["post_warmup_traces"] != 0:
        errors.append(
            f"autotuned-ladder engine retraced after warmup: "
            f"{lad['autotuned']['post_warmup_traces']} post-warmup traces "
            "(VersionCache.warmup must prebuild every ladder level)")
    return errors


def check_slo(s: dict | None) -> list[str]:
    """The SLO-tiered scheduling gates (all strict: virtual time)."""
    if not s or "fifo" not in s or "slo" not in s:
        return ["BENCH_serving.json has no slo section (stale file?) — "
                "rerun `python -m benchmarks.bench_online_serving --tiny`"]
    errors = []
    fifo_q, slo_q = s["fifo"]["qps_at_qos"], s["slo"]["qps_at_qos"]
    if not slo_q >= SLO_GAIN_MIN * fifo_q:
        errors.append(
            f"SLO-tiered scheduling lost its queries-under-QoS win: "
            f"{slo_q} qps_at_qos vs fifo's {fifo_q} "
            f"(need >= {SLO_GAIN_MIN}x at equal offered load)")
    rates = s["slo"]["per_tier_qos_rate"]
    missing = [t for t in SLO_TIER_ORDER if t not in rates]
    if missing:
        errors.append(f"slo arm is missing tier slices {missing} — the "
                      "workload no longer exercises all three tiers")
    else:
        for hi, lo in zip(SLO_TIER_ORDER, SLO_TIER_ORDER[1:]):
            if not rates[hi] >= rates[lo]:
                errors.append(
                    f"tier inversion under the slo schedule: {hi} "
                    f"qos_rate {rates[hi]} < {lo} qos_rate {rates[lo]} "
                    "(tighter tiers must never fare worse)")
    if not s.get("token_identical", False):
        errors.append(
            "fifo and slo schedules produced different per-request token "
            "streams — scheduling must reorder quanta, never change what "
            "a request computes")
    if s.get("common_requests", 0) <= 0:
        errors.append("fifo and slo arms served no common requests — the "
                      "token-identity check is vacuous")
    sp = s.get("slo_spec")
    if not sp:
        errors.append("slo section has no slo_spec arm (stale file?) — "
                      "rerun `python -m benchmarks.bench_online_serving "
                      "--tiny`")
    else:
        # speculation jitters EDF's quantum picks (expected-accept slack
        # scaling), so its qps_at_qos is not bit-equal to the plain slo
        # arm's; the invariant that matters is that the PR-6 headline
        # win survives with speculation on
        if not sp["qps_at_qos"] >= SLO_GAIN_MIN * fifo_q:
            errors.append(
                f"speculation broke the SLO scheduler's "
                f"queries-under-QoS win: {sp['qps_at_qos']} qps_at_qos "
                f"vs fifo's {fifo_q} (need >= {SLO_GAIN_MIN}x — the "
                f"plain slo arm holds {slo_q})")
        if not s.get("spec_token_identical", False):
            errors.append(
                "slo and slo_spec arms produced different token streams "
                "on commonly-served requests — speculation must change "
                "the schedule, never the tokens")
    return errors


def check_paged(p: dict | None) -> list[str]:
    """The paged-KV-cache gates (all strict: virtual time)."""
    if not p or "dense" not in p or "paged" not in p:
        return ["BENCH_serving.json has no paged section (stale file?) — "
                "rerun `python -m benchmarks.bench_online_serving --tiny`"]
    errors = []
    budget = p["memory_budget_tokens"]
    for arm in ("dense", "paged"):
        if p[arm]["peak_resident_tokens"] > budget:
            errors.append(
                f"{arm} arm exceeded the device memory budget: "
                f"{p[arm]['peak_resident_tokens']} resident tokens > "
                f"{budget} — the comparison is not at equal memory")
    gain = p["paged"]["peak_concurrent"] \
        / max(p["dense"]["peak_concurrent"], 1)
    if not gain >= PAGED_GAIN_MIN:
        errors.append(
            f"paged KV cache lost its concurrency win at equal memory: "
            f"{p['paged']['peak_concurrent']} peak concurrent vs dense's "
            f"{p['dense']['peak_concurrent']} "
            f"(need >= {PAGED_GAIN_MIN}x on a {budget}-token budget)")
    if not p.get("token_identical", False):
        errors.append(
            "dense and paged engines produced different per-request token "
            "streams — the page table must change where KV lives, never "
            "what a request computes")
    if p["paged"]["post_warmup_traces"] != 0:
        errors.append(
            f"paged engine retraced after warmup: "
            f"{p['paged']['post_warmup_traces']} post-warmup traces "
            "(paged gather/scatter paths must be fully warmed)")
    tiny = p.get("tiny_pool", {})
    if tiny.get("shed", 0) + tiny.get("deferred", 0) <= 0:
        errors.append(
            "page-pool exhaustion produced no shed/deferred admissions — "
            "memory pressure must surface as a counted scheduling "
            "decision, never a silent stall")
    cluster = p.get("cluster", {})
    if cluster.get("shared_hits", 0) < 1:
        errors.append(
            "cross-request prefix sharing deduplicated zero pages across "
            "co-located tenants — the prefix index is not being hit")
    return errors


def main() -> int:
    path = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else DEFAULT
    try:
        errors = check(path)
    except (KeyError, TypeError) as e:
        # a stale file from an older bench schema: name the missing key
        # in one line instead of dumping a traceback
        print(f"check_bench: FAIL: {path.name} is stale — missing/"
              f"malformed key {e.args[0]!r}; {RERUN}", file=sys.stderr)
        return 1
    for e in errors:
        print("BENCH REGRESSION:", e)
    if errors:
        return 1
    data = load(path)
    try:
        return summarize(data)
    except (KeyError, TypeError) as e:
        print(f"check_bench: FAIL: {path.name} is stale — missing/"
              f"malformed key {e.args[0]!r} in the summary sections; "
              f"{RERUN}", file=sys.stderr)
        return 1


def summarize(data: dict) -> int:
    print(f"bench gate: fused dispatch wins "
          f"({data['quantum']['speedup_tokens_per_s']}x tokens/s, "
          f"{data['quantum']['fused']['tokens_per_sync']} tokens/sync)")
    if data.get("prefill"):
        p = data["prefill"]
        print(f"bench gate: chunked prefill holds zero retraces "
              f"({p['chunked']['post_warmup_traces']} vs monolithic's "
              f"{p['monolithic']['post_warmup_traces']} on mixed lengths)")
    if data.get("slo"):
        s = data["slo"]
        rates = s["slo"]["per_tier_qos_rate"]
        print(f"bench gate: slo scheduling serves "
              f"{s['gain_qps_at_qos']}x fifo's queries-under-QoS "
              f"({s['slo']['qps_at_qos']} vs {s['fifo']['qps_at_qos']} "
              f"qps_at_qos; tiers "
              + "/".join(f"{t}={rates[t]}" for t in SLO_TIER_ORDER
                         if t in rates)
              + f"; token_identical={s['token_identical']}"
              + (f"; with speculation "
                 f"{s['slo_spec']['qps_at_qos']} qps_at_qos"
                 if s.get("slo_spec") else "") + ")")
    if data.get("paged"):
        p = data["paged"]
        print(f"bench gate: paged KV cache sustains "
              f"{p['concurrency_gain']}x dense's peak concurrency "
              f"({p['paged']['peak_concurrent']} vs "
              f"{p['dense']['peak_concurrent']} requests on a "
              f"{p['memory_budget_tokens']}-token budget; "
              f"shared_hits={p['paged']['page_stats']['shared_hits']}; "
              f"deferred={p['tiny_pool']['deferred']}; "
              f"cluster_shared={p['cluster']['shared_hits']}; "
              f"token_identical={p['token_identical']})")
    if data.get("measured"):
        mm = data["measured"]
        print(f"bench gate: measured-counter proxy holds "
              f"{mm['proxy']['rms_ratio']}x the calibration residual "
              f"(measured {mm['proxy']['measured_rms']} vs oracle "
              f"{mm['proxy']['oracle_rms']}; "
              f"refits={mm['proxy']['refits']}; "
              f"measured_polls={mm['proxy']['polls'].get('measured', 0)}); "
              f"autotuned ladder serves "
              f"{mm['ladder']['gain_qps_at_qos']}x the fixed table's "
              f"queries-under-QoS with "
              f"{mm['ladder']['autotuned']['post_warmup_traces']} "
              f"post-warmup traces")
    if data.get("spec"):
        sp = data["spec"]
        rep, adv = sp["repetitive"], sp["adversarial"]
        print(f"bench gate: speculative decode serves "
              f"{rep['speedup_tokens_per_s']}x the plain fused tokens/s "
              f"on the repetitive workload "
              f"({rep['spec']['tokens_per_s']} vs "
              f"{rep['plain']['tokens_per_s']} tok/s; hit rate "
              f"{rep['spec']['draft_hit_rate']}; "
              f"{rep['spec']['post_warmup_traces']} post-warmup traces; "
              f"token_identical={rep['token_identical']}); adversarial "
              f"arm at {adv['speedup_tokens_per_s']}x plain")
    return 0


if __name__ == "__main__":
    sys.exit(main())
