"""Bench gate: the fused dispatch quantum must actually win.

Reads BENCH_serving.json (written by ``python -m
benchmarks.bench_online_serving [--tiny]`` at the repo root) and fails
if the fused quantum path's warm decode throughput regressed below the
per-step dispatch loop, or if fusion stopped coarsening the host
boundary (tokens per device->host sync back at ~1).  Run from the repo
root:

    python -m benchmarks.bench_online_serving --tiny
    python tools/check_bench.py

Exit code 0 = fused dispatch holds its win; 1 = regression (each failed
check is printed).
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT = ROOT / "BENCH_serving.json"


def check(path: pathlib.Path) -> list[str]:
    if not path.exists():
        return [f"{path} missing — run "
                "`python -m benchmarks.bench_online_serving --tiny` first"]
    data = json.loads(path.read_text())
    q = data.get("quantum")
    if not q or "fused" not in q or "per_step" not in q:
        return [f"{path} has no quantum section (stale file?)"]
    fused, per_step = q["fused"], q["per_step"]
    errors = []
    if not fused["tokens_per_s"] > per_step["tokens_per_s"]:
        errors.append(
            f"fused warm decode regressed below per-step dispatch: "
            f"{fused['tokens_per_s']} <= {per_step['tokens_per_s']} tok/s")
    # deterministic (load-independent) check: fusion must coarsen the host
    # boundary RELATIVE to the per-step baseline — batching/admissions
    # already put the per-step arm above 1 token/sync, so comparing
    # against a constant would miss fusion degenerating to 1-step quanta
    if not fused["tokens_per_sync"] > 1.5 * per_step["tokens_per_sync"]:
        errors.append(
            f"fused path is not coarsening the host boundary: "
            f"{fused['tokens_per_sync']} tokens/sync vs per-step's "
            f"{per_step['tokens_per_sync']} (expected > 1.5x)")
    if fused["tokens"] != per_step["tokens"]:
        errors.append(
            f"fused and per-step runs decoded different token counts "
            f"({fused['tokens']} vs {per_step['tokens']}) — the comparison "
            "is not apples-to-apples")
    return errors


def main() -> int:
    path = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else DEFAULT
    errors = check(path)
    for e in errors:
        print("BENCH REGRESSION:", e)
    if errors:
        return 1
    data = json.loads(path.read_text())
    print(f"bench gate: fused dispatch wins "
          f"({data['quantum']['speedup_tokens_per_s']}x tokens/s, "
          f"{data['quantum']['fused']['tokens_per_sync']} tokens/sync)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
