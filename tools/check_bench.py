"""Bench gate: the fused dispatch quantum must actually win.

Reads BENCH_serving.json (written by ``python -m
benchmarks.bench_online_serving [--tiny]`` at the repo root) and fails
if the fused quantum path's warm decode throughput regressed below the
per-step dispatch loop (minus a noise tolerance — wall-clock on shared
runners is not deterministic), if fusion stopped coarsening the host
boundary (tokens per device->host sync back at ~1; strict — counted,
not timed), or if the chunked prefill path retraced under mixed-length
traffic (strict).  Run from the repo root:

    python -m benchmarks.bench_online_serving --tiny
    python tools/check_bench.py

Exit code 0 = fused dispatch holds its win; 1 = regression (each failed
check is printed).
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT = ROOT / "BENCH_serving.json"

# Wall-clock throughput on shared CI runners is noisy even after
# best-of-N; requiring fused to STRICTLY beat per-step with zero margin
# flaked on correlated load spikes.  Fused must stay within this
# fraction of per-step (a real regression — fusion overhead eating the
# win — shows up far below it); the tokens-per-sync check stays strict
# because it is deterministic (counted, not timed).
THROUGHPUT_TOLERANCE = 0.10


def check(path: pathlib.Path) -> list[str]:
    if not path.exists():
        return [f"{path} missing — run "
                "`python -m benchmarks.bench_online_serving --tiny` first"]
    data = json.loads(path.read_text())
    q = data.get("quantum")
    if not q or "fused" not in q or "per_step" not in q:
        return [f"{path} has no quantum section (stale file?)"]
    fused, per_step = q["fused"], q["per_step"]
    errors = []
    floor = (1.0 - THROUGHPUT_TOLERANCE) * per_step["tokens_per_s"]
    if not fused["tokens_per_s"] >= floor:
        errors.append(
            f"fused warm decode regressed below per-step dispatch: "
            f"{fused['tokens_per_s']} < {floor:.1f} tok/s "
            f"(per-step {per_step['tokens_per_s']} minus "
            f"{THROUGHPUT_TOLERANCE:.0%} noise tolerance)")
    # deterministic (load-independent) check: fusion must coarsen the host
    # boundary RELATIVE to the per-step baseline — batching/admissions
    # already put the per-step arm above 1 token/sync, so comparing
    # against a constant would miss fusion degenerating to 1-step quanta
    if not fused["tokens_per_sync"] > 1.5 * per_step["tokens_per_sync"]:
        errors.append(
            f"fused path is not coarsening the host boundary: "
            f"{fused['tokens_per_sync']} tokens/sync vs per-step's "
            f"{per_step['tokens_per_sync']} (expected > 1.5x)")
    if fused["tokens"] != per_step["tokens"]:
        errors.append(
            f"fused and per-step runs decoded different token counts "
            f"({fused['tokens']} vs {per_step['tokens']}) — the comparison "
            "is not apples-to-apples")
    # mixed-length admission path (deterministic): the chunked/bucketed
    # prefill must perform zero post-warmup retraces, and the monolithic
    # arm is the counterexample that keeps the comparison honest
    p = data.get("prefill")
    if p and "chunked" in p:
        if p["chunked"]["post_warmup_traces"] != 0:
            errors.append(
                f"chunked prefill retraced under mixed-length traffic: "
                f"{p['chunked']['post_warmup_traces']} post-warmup traces "
                "(bucket table must cover every admitted length)")
        if "monolithic" in p and \
                p["monolithic"]["post_warmup_traces"] == 0:
            errors.append(
                "monolithic prefill arm performed zero retraces on a "
                "mixed-length workload — the benchmark is not actually "
                "exercising the length spread")
    return errors


def main() -> int:
    path = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else DEFAULT
    errors = check(path)
    for e in errors:
        print("BENCH REGRESSION:", e)
    if errors:
        return 1
    data = json.loads(path.read_text())
    print(f"bench gate: fused dispatch wins "
          f"({data['quantum']['speedup_tokens_per_s']}x tokens/s, "
          f"{data['quantum']['fused']['tokens_per_sync']} tokens/sync)")
    if data.get("prefill"):
        p = data["prefill"]
        print(f"bench gate: chunked prefill holds zero retraces "
              f"({p['chunked']['post_warmup_traces']} vs monolithic's "
              f"{p['monolithic']['post_warmup_traces']} on mixed lengths)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
