"""Docs gate: every module path referenced in docs/ARCHITECTURE.md (and
README.md) must import, and every ``repro.module:Symbol`` reference must
resolve via getattr.  Run from the repo root:

    PYTHONPATH=src python tools/check_docs.py

Exit code 0 = all references importable; 1 = any broken reference (each
is printed).  CI runs this in the fast job so the paper-to-code map can
never drift from the codebase silently.
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = [ROOT / "docs" / "ARCHITECTURE.md", ROOT / "README.md"]

# Sections a doc must carry to count as current: a doc that imports
# cleanly but lost (or predates) one of these is stale, and the gate
# names the missing section in one line instead of silently passing.
REQUIRED_SECTIONS = {
    "ARCHITECTURE.md": ("## 1. Paper-to-code map",
                        "## 11. Static invariant checking"),
    "README.md": ("## Correctness gates",),
}

# `repro.pkg.mod` or `repro.pkg.mod:Symbol` inside backticks
REF = re.compile(r"`(repro(?:\.[A-Za-z0-9_]+)+)(?::([A-Za-z0-9_]+))?`")


def check(path: pathlib.Path) -> list[str]:
    errors = []
    seen: set[tuple[str, str | None]] = set()
    for mod, sym in REF.findall(path.read_text()):
        key = (mod, sym or None)
        if key in seen:
            continue
        seen.add(key)
        try:
            m = importlib.import_module(mod)
        except ModuleNotFoundError:
            # prose often writes `repro.pkg.mod.Symbol` — accept the last
            # dotted component as an attribute of the parent module
            parent, _, attr = mod.rpartition(".")
            try:
                m = importlib.import_module(parent)
            except Exception as e:                  # noqa: BLE001
                errors.append(f"{path.name}: `{mod}` does not import: "
                              f"{e!r}")
                continue
            if not hasattr(m, attr):
                errors.append(f"{path.name}: `{mod}` — neither a module "
                              f"nor an attribute of {parent}")
                continue
        except Exception as e:                      # noqa: BLE001
            errors.append(f"{path.name}: `{mod}` does not import: {e!r}")
            continue
        if sym and not hasattr(m, sym):
            errors.append(f"{path.name}: `{mod}:{sym}` — module imports "
                          f"but has no attribute {sym!r}")
    print(f"{path.name}: {len(seen)} module references checked")
    return errors


def check_sections(path: pathlib.Path) -> list[str]:
    text = path.read_text()
    return [f"{path.name}: missing required section {h!r} — the doc is "
            f"stale (update it alongside the code it maps)"
            for h in REQUIRED_SECTIONS.get(path.name, ())
            if h not in text]


def main() -> int:
    missing = [d for d in DOCS if not d.exists()]
    if missing:
        for d in missing:
            print(f"check_docs: FAIL: required doc file is absent: "
                  f"{d.relative_to(ROOT)}", file=sys.stderr)
        return 1
    errors = [e for d in DOCS for e in check(d)]
    errors += [e for d in DOCS for e in check_sections(d)]
    for e in errors:
        print("BROKEN:", e)
    if errors:
        return 1
    print("docs gate: all module references importable")
    return 0


if __name__ == "__main__":
    sys.exit(main())
