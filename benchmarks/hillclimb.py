"""Perf hillclimbing driver — hypothesis -> change -> measure -> validate.

Measures a cell's roofline terms under named variants (sharding rules,
config tweaks, train knobs) and appends records to
results/hillclimb.jsonl.  The §Perf log in EXPERIMENTS.md is written from
these records.

    PYTHONPATH=src:. python benchmarks/hillclimb.py --cell gemma-decode \
        --variant baseline seqshard
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json

from repro.configs import get_config, get_shape
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh

import benchmarks.roofline as R

RESULTS = R.RESULTS


def measure_variant(arch: str, shape_name: str, *, rules=None, cfg=None,
                    accum: int | None = None, label: str = "baseline"):
    """Roofline terms for one cell variant (d1/d2 extrapolated)."""
    base_cfg = cfg or get_config(arch)
    shape = get_shape(shape_name)
    from repro.models.model import make_plan
    plan = make_plan(base_cfg)
    mesh = make_production_mesh()
    eff_accum = accum if accum is not None else (
        R.TRAIN_KNOBS[arch][1] if shape.mode == "train" else 1)
    mb_shape = (dataclasses.replace(
        shape, global_batch=max(shape.global_batch // eff_accum, 1))
        if eff_accum > 1 else shape)

    def meas(groups):
        return R._measure(arch, shape_name, R._depth_cfg(base_cfg, groups),
                          mesh, mb_shape, rules=rules)

    d1, d2 = meas(1), meas(2)
    totals = {k: (d1[k] + (plan.n_groups - 1) * (d2[k] - d1[k])) * eff_accum
              for k in ("flops", "bytes", "link")}
    rec = {
        "cell": f"{arch}x{shape_name}", "variant": label,
        "accum": eff_accum,
        "compute_s": totals["flops"] / R.PEAK_FLOPS,
        "memory_s": totals["bytes"] / R.HBM_BW,
        "collective_s": totals["link"] / R.LINK_BW,
    }
    rec["bound_s"] = max(rec["compute_s"], rec["memory_s"],
                         rec["collective_s"])
    rec["dominant"] = max(
        ("compute", rec["compute_s"]), ("memory", rec["memory_s"]),
        ("collective", rec["collective_s"]), key=lambda kv: kv[1])[0]
    with open(os.path.join(RESULTS, "hillclimb.jsonl"), "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(f"[hillclimb] {rec['cell']} {label}: "
          f"comp={rec['compute_s']*1e3:.2f}ms mem={rec['memory_s']*1e3:.2f}ms "
          f"coll={rec['collective_s']*1e3:.2f}ms dom={rec['dominant']}",
          flush=True)
    return rec


# named variants --------------------------------------------------------------
def gemma_decode(variants):
    arch, shp = "gemma-2b", "decode_32k"
    if "baseline" in variants:
        measure_variant(arch, shp, label="baseline")
    if "seqshard" in variants:
        # context-parallel decode: shard the KV-cache sequence axis over
        # the (otherwise idle, kv_heads=1) model axis
        rules = shd.make_rules("serve", False, seq_parallel=True)
        measure_variant(arch, shp, rules=rules, label="seqshard-kv")


def arctic_train(variants):
    arch, shp = "arctic-480b", "train_4k"
    if "baseline" in variants:
        measure_variant(arch, shp, label="baseline(accum16)")
    for v in variants:
        if v.startswith("accum"):
            measure_variant(arch, shp, accum=int(v[5:]),
                            label=f"accum{int(v[5:])}")


def deepseek_decode(variants):
    arch, shp = "deepseek-v2-lite-16b", "decode_32k"
    cfg = get_config(arch)
    if "baseline" in variants:
        measure_variant(arch, shp, label="baseline(plain-mla)")
    if "absorb" in variants:
        cfg2 = dataclasses.replace(
            cfg, mla=dataclasses.replace(cfg.mla, absorb=True))
        measure_variant(arch, shp, cfg=cfg2, label="mla-absorb")
    if "absorb-seqshard" in variants:
        cfg2 = dataclasses.replace(
            cfg, mla=dataclasses.replace(cfg.mla, absorb=True))
        rules = shd.make_rules("serve", False, seq_parallel=True)
        measure_variant(arch, shp, cfg=cfg2, rules=rules,
                        label="mla-absorb+seqshard")


CELLS = {"gemma-decode": gemma_decode, "arctic-train": arctic_train,
         "deepseek-decode": deepseek_decode}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--variant", nargs="+", default=["baseline"])
    args = ap.parse_args()
    CELLS[args.cell](args.variant)


if __name__ == "__main__":
    main()
