"""Perf hillclimbing driver — hypothesis -> change -> measure -> validate.

Two entry points share the greedy search core here:

* the roofline variant driver (``main``): measures a cell's roofline
  terms under named variants (sharding rules, config tweaks, train
  knobs) and appends records to results/hillclimb.jsonl.  The §Perf log
  in EXPERIMENTS.md is written from these records.

      PYTHONPATH=src:. python benchmarks/hillclimb.py --cell gemma-decode \
          --variant baseline seqshard

* the tile-ladder autotuner (:func:`search_tile_ladder`, driven by
  ``tools/autotune_ladder.py``): per interference-grid level, hillclimb
  the (bm, bk, bn) tile lattice of ``schedule_space.enumerate_versions``
  candidates under the analytic cost model, warm-started from the
  previous level's winner and constrained to a non-growing matmul
  working set — which makes the emitted :class:`LadderSpec` satisfy its
  exclusive->shared ordering invariant by construction.

The heavy roofline dependencies (mesh construction, model plans, the
512-device XLA host-platform flag) are imported lazily inside the
functions that need them, so importing this module for the search
helpers costs nothing.
"""
import argparse
import dataclasses
import json
import os

from repro.core import cost_model as cm
from repro.core import schedule_space as ss
from repro.core.multiversion import LadderSpec, _matmul_bytes


# -- greedy search core -------------------------------------------------------
def local_search(start, neighbors_fn, score_fn, max_iters: int = 64):
    """Greedy hillclimb from ``start``: move to the best-scoring neighbor
    while one improves.  Scores are memoized per state (states must be
    hashable).  Returns ``(best_state, best_score, iters)``."""
    scores: dict = {}

    def score(s):
        hit = scores.get(s)
        if hit is None:
            hit = scores[s] = score_fn(s)
        return hit

    cur, cur_score = start, score(start)
    iters = 0
    for _ in range(max_iters):
        cands = [n for n in neighbors_fn(cur) if n != cur]
        if not cands:
            break
        best = min(cands, key=score)
        if score(best) >= cur_score:
            break
        cur, cur_score = best, score(best)
        iters += 1
    return cur, cur_score, iters


def _attention_tiles(bm: int) -> dict:
    """Attention tiling derived from the matmul M-tile — the same
    footprint coupling ``DEFAULT_LEVEL_TILES`` uses (the search space is
    the GEMM lattice; attention follows its locality scale)."""
    return {"bq": max(int(bm), 64), "bkv": max(2 * int(bm), 128)}


def search_tile_ladder(layer: cm.GemmLayer, hw: cm.HardwareSpec, *,
                       tiles=ss.TILES, unrolls=ss.UNROLLS,
                       units: int | None = None,
                       name: str | None = None,
                       max_iters: int = 64) -> LadderSpec:
    """Autotune a full interference-level -> tile-table ladder for one
    representative layer.

    Per grid level: hillclimb the (bm, bk, bn) lattice minimizing
    ``cost_model.latency`` at that level's pressure, warm-started from
    the previous level's winner, with candidates restricted to a matmul
    working set no larger than that winner's.  The restriction is the
    ladder's validate() invariant, enforced during search rather than
    patched up after.
    """
    units = units or max(hw.n_units // 4, 1)
    cands = ss.enumerate_versions(layer, hw, tiles=tiles, unrolls=unrolls)
    if not cands:
        raise ValueError(f"no feasible tile candidates for {layer.name} "
                         f"on {hw.name}")
    # best version (over unroll) per tiling — the hillclimb walks tilings
    by_tiling: dict[tuple, cm.CodeVersion] = {}
    for v in cands:
        key = (v.bm, v.bk, v.bn)
        cur = by_tiling.get(key)
        if cur is None or cm.latency(hw, v, units, cm.Interference()) < \
                cm.latency(hw, cur, units, cm.Interference()):
            by_tiling[key] = v
    axes = tuple(sorted({k[i] for k in by_tiling}) for i in range(3))

    def neighbors(key):
        out = []
        for i in range(3):
            axis = axes[i]
            j = axis.index(key[i])
            for dj in (-1, 1):
                if 0 <= j + dj < len(axis):
                    nk = list(key)
                    nk[i] = axis[j + dj]
                    nk = tuple(nk)
                    if nk in by_tiling:
                        out.append(nk)
        return out

    def bytes_of(key) -> int:
        return _matmul_bytes({"matmul": {"bm": key[0], "bk": key[1],
                                         "bn": key[2]}})

    levels, scores = [], []
    prev_key, cap = None, None
    for itf in cm.level_grid():
        def score(key):
            if cap is not None and bytes_of(key) > cap:
                return float("inf")
            return cm.latency(hw, by_tiling[key], units, itf)

        if prev_key is None:
            start = min(by_tiling, key=score)
        else:
            start = prev_key          # warm start: always feasible (== cap)
        best, best_s, _ = local_search(start, neighbors, score,
                                       max_iters=max_iters)
        bm, bk, bn = best
        levels.append({"matmul": {"bm": bm, "bk": bk, "bn": bn},
                       "attention": _attention_tiles(bm)})
        scores.append(float(best_s))
        prev_key, cap = best, bytes_of(best)

    spec = LadderSpec(
        name=name or f"{layer.name}@{hw.name}", hw=hw.name,
        levels=levels, scores=scores,
        meta={"layer": layer.name, "units": units,
              "m": layer.m, "k": layer.k, "n": layer.n,
              "tiles": [int(t) for t in tiles],
              "unrolls": [int(u) for u in unrolls]})
    spec.validate()
    return spec


# -- roofline variant driver (heavy imports stay lazy) ------------------------
def _roofline():
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    import benchmarks.roofline as R
    return R


def measure_variant(arch: str, shape_name: str, *, rules=None, cfg=None,
                    accum: int | None = None, label: str = "baseline"):
    """Roofline terms for one cell variant (d1/d2 extrapolated)."""
    R = _roofline()
    from repro.configs import get_config, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import make_plan

    base_cfg = cfg or get_config(arch)
    shape = get_shape(shape_name)
    plan = make_plan(base_cfg)
    mesh = make_production_mesh()
    eff_accum = accum if accum is not None else (
        R.TRAIN_KNOBS[arch][1] if shape.mode == "train" else 1)
    mb_shape = (dataclasses.replace(
        shape, global_batch=max(shape.global_batch // eff_accum, 1))
        if eff_accum > 1 else shape)

    def meas(groups):
        return R._measure(arch, shape_name, R._depth_cfg(base_cfg, groups),
                          mesh, mb_shape, rules=rules)

    d1, d2 = meas(1), meas(2)
    totals = {k: (d1[k] + (plan.n_groups - 1) * (d2[k] - d1[k])) * eff_accum
              for k in ("flops", "bytes", "link")}
    rec = {
        "cell": f"{arch}x{shape_name}", "variant": label,
        "accum": eff_accum,
        "compute_s": totals["flops"] / R.PEAK_FLOPS,
        "memory_s": totals["bytes"] / R.HBM_BW,
        "collective_s": totals["link"] / R.LINK_BW,
    }
    rec["bound_s"] = max(rec["compute_s"], rec["memory_s"],
                         rec["collective_s"])
    rec["dominant"] = max(
        ("compute", rec["compute_s"]), ("memory", rec["memory_s"]),
        ("collective", rec["collective_s"]), key=lambda kv: kv[1])[0]
    with open(os.path.join(R.RESULTS, "hillclimb.jsonl"), "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(f"[hillclimb] {rec['cell']} {label}: "
          f"comp={rec['compute_s']*1e3:.2f}ms mem={rec['memory_s']*1e3:.2f}ms "
          f"coll={rec['collective_s']*1e3:.2f}ms dom={rec['dominant']}",
          flush=True)
    return rec


# named variants --------------------------------------------------------------
def gemma_decode(variants):
    arch, shp = "gemma-2b", "decode_32k"
    if "baseline" in variants:
        measure_variant(arch, shp, label="baseline")
    if "seqshard" in variants:
        # context-parallel decode: shard the KV-cache sequence axis over
        # the (otherwise idle, kv_heads=1) model axis
        from repro.dist import sharding as shd
        rules = shd.make_rules("serve", False, seq_parallel=True)
        measure_variant(arch, shp, rules=rules, label="seqshard-kv")


def arctic_train(variants):
    arch, shp = "arctic-480b", "train_4k"
    if "baseline" in variants:
        measure_variant(arch, shp, label="baseline(accum16)")
    for v in variants:
        if v.startswith("accum"):
            measure_variant(arch, shp, accum=int(v[5:]),
                            label=f"accum{int(v[5:])}")


def deepseek_decode(variants):
    arch, shp = "deepseek-v2-lite-16b", "decode_32k"
    from repro.configs import get_config
    from repro.dist import sharding as shd
    cfg = get_config(arch)
    if "baseline" in variants:
        measure_variant(arch, shp, label="baseline(plain-mla)")
    if "absorb" in variants:
        cfg2 = dataclasses.replace(
            cfg, mla=dataclasses.replace(cfg.mla, absorb=True))
        measure_variant(arch, shp, cfg=cfg2, label="mla-absorb")
    if "absorb-seqshard" in variants:
        cfg2 = dataclasses.replace(
            cfg, mla=dataclasses.replace(cfg.mla, absorb=True))
        rules = shd.make_rules("serve", False, seq_parallel=True)
        measure_variant(arch, shp, cfg=cfg2, rules=rules,
                        label="mla-absorb+seqshard")


CELLS = {"gemma-decode": gemma_decode, "arctic-train": arctic_train,
         "deepseek-decode": deepseek_decode}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--variant", nargs="+", default=["baseline"])
    args = ap.parse_args()
    CELLS[args.cell](args.variant)


if __name__ == "__main__":
    main()
