"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
Figure mapping (paper -> section): see DESIGN.md §6.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="3-point QPS grids instead of 5")
    args = ap.parse_args()

    from benchmarks import bench_figures as F
    from benchmarks import bench_kernels as K
    from benchmarks import bench_online_serving as O

    t0 = time.time()
    print("name,us_per_call,derived")
    K.run_all()
    O.run_all()
    F.fig4_core_scaling()
    F.fig6_multiversion()
    F.fig7_version_count()
    F.fig11_proxy()
    F.fig3_granularity()
    F.fig5_conflicts()
    out12 = F.fig12_qps(quick=args.quick)
    F.fig13_latency(out12)
    F.fig14_efficiency()

    # append dry-run / roofline / hillclimb summaries from results/*.jsonl
    try:
        from benchmarks import report
        report.main()
    except Exception as e:  # reports are optional if sweeps haven't run
        print(f"# report unavailable: {e}", file=sys.stderr)
    print(f"# total wall: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
