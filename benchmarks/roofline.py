"""Roofline analysis per (arch x shape) cell — deliverable (g).

Per-device cost terms come from compiled artifacts, but XLA's
cost_analysis does NOT multiply while-loop bodies by trip count (scanned
layer stacks and grad-accumulation loops report one iteration).  We
therefore lower each cell twice at reduced depth — d1 = one scan group,
d2 = two groups — on the production mesh with the production shardings,
and extrapolate linearly:

    total(X) = X(d1) + (n_groups - 1) * (X(d2) - X(d1)),   then x accum

for X in {flops, bytes, link_bytes}.  All layers in a group are identical,
so the per-group delta is exact; the d1 base carries embed/unembed/optimizer
costs.  Records land in results/roofline_cells.json.

Terms (v5e constants from the assignment):
    compute_s    = flops_dev   / 197e12
    memory_s     = bytes_dev   / 819e9
    collective_s = link_bytes_dev / 50e9
    MODEL_FLOPS  = 6*N_active*D (train) or 2*N_active*D (inference)
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json

import jax

from repro.configs import get_config, get_shape, all_cells
from repro.core.profiles import model_flops
from repro.launch import hlo_stats
from repro.launch.dryrun import TRAIN_KNOBS, build_cell
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, param_count
from repro.models.model import make_plan

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "results"))


def _depth_cfg(cfg, groups: int):
    plan = make_plan(cfg)
    per = len(plan.scan_kinds)
    layers = groups * per + len(plan.prologue)
    return dataclasses.replace(cfg, name=f"{cfg.name}-d{groups}",
                               num_layers=layers)


def _measure(arch, shape_name, cfg, mesh, shape=None, rules=None):
    from repro.models import layers as L
    fn, args_abs, in_sh, donate, _ = build_cell(
        arch, shape_name, mesh, False, cfg=cfg, accum_override=1,
        shape=shape, rules=rules)
    L.ANALYSIS_UNROLL = True
    try:
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh,
                               donate_argnums=donate
                               ).lower(*args_abs).compile()
            hlo = compiled.as_text()
    finally:
        L.ANALYSIS_UNROLL = False
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = hlo_stats.parse_collectives(hlo)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "link": float(coll.link_bytes)}


def n_active_params(cfg) -> int:
    """Active params per token (MoE counts top-k + shared + dense only)."""
    total = param_count(build_model(cfg).param_specs())
    if cfg.moe is None:
        return total
    moe = cfg.moe
    expert_params = 3 * cfg.d_model * moe.expert_d_ff
    inactive = (moe.num_experts - moe.top_k) * expert_params \
        * (cfg.num_layers - cfg.first_dense_layers)
    return total - inactive


def roofline_cell(arch: str, shape_name: str, mesh) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    plan = make_plan(cfg)
    # measure at the true microbatch size (grad-accum repeats the whole
    # fwd/bwd — weights re-stream and re-gather per microbatch), scale back
    accum = TRAIN_KNOBS[arch][1] if shape.mode == "train" else 1
    mb_shape = (dataclasses.replace(shape,
                                    global_batch=max(shape.global_batch
                                                     // accum, 1))
                if accum > 1 else shape)
    d1 = _measure(arch, shape_name, _depth_cfg(cfg, 1), mesh, mb_shape)
    d2 = _measure(arch, shape_name, _depth_cfg(cfg, 2), mesh, mb_shape)
    totals = {}
    for k in ("flops", "bytes", "link"):
        per_group = d2[k] - d1[k]
        totals[k] = (d1[k] + (plan.n_groups - 1) * per_group) * accum
    compute_s = totals["flops"] / PEAK_FLOPS
    memory_s = totals["bytes"] / HBM_BW
    coll_s = totals["link"] / LINK_BW

    # Analytic compulsory-traffic floor: weights stream once per microbatch
    # (x accum), KV/state caches read+write once, activations ~2 x residual
    # stream per layer.  The HLO byte count from the CPU backend overcounts
    # (different fusion decisions than TPU), so we report both and use the
    # geometric mean of (floor, HLO) for bottleneck calls.
    model = build_model(cfg)
    from repro.models.params import param_bytes
    wb = param_bytes(model.param_specs()) / mesh.size
    tokens = (shape.global_batch if shape.mode == "decode"
              else shape.global_batch * shape.seq_len)
    act_b = 2 * 2 * tokens * cfg.d_model * max(cfg.num_layers, 1) \
        / mesh.size
    cache_b = 0.0
    if shape.mode == "decode":
        cache_b = 2 * param_bytes(
            model.cache_specs(shape.global_batch, shape.seq_len)) / mesh.size
    bytes_floor = wb * accum + act_b * accum + cache_b
    memory_floor_s = bytes_floor / HBM_BW
    memory_est_s = (memory_s * memory_floor_s) ** 0.5

    dominant = max(("compute", compute_s), ("memory", memory_est_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]
    n_act = n_active_params(cfg)
    mult = 6 if shape.mode == "train" else 2
    mflops_dev = mult * n_act * tokens / mesh.size
    hlo_total = max(totals["flops"], 1.0)
    bound = max(compute_s, memory_est_s, coll_s)
    return {
        "arch": arch, "shape": shape_name, "mode": shape.mode,
        "n_devices": mesh.size, "accum": accum,
        "flops_dev": totals["flops"], "bytes_dev": totals["bytes"],
        "bytes_floor_dev": bytes_floor,
        "link_bytes_dev": totals["link"],
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_floor_s": memory_floor_s, "memory_est_s": memory_est_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops_dev": mflops_dev,
        "useful_flops_ratio": mflops_dev / hlo_total,
        "bound_s": bound,
        "roofline_fraction": compute_s / bound,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default=os.path.join(RESULTS,
                                                  "roofline_cells.jsonl"))
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False)
    os.makedirs(RESULTS, exist_ok=True)
    for arch, shape_name, ok, _ in all_cells(include_skipped=False):
        if args.arch != "all" and arch != args.arch:
            continue
        if args.shape != "all" and shape_name != args.shape:
            continue
        try:
            rec = roofline_cell(arch, shape_name, mesh)
        except Exception as e:
            rec = {"arch": arch, "shape": shape_name,
                   "error": f"{type(e).__name__}: {e}"}
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        if "error" in rec:
            print(f"[roofline] {arch} x {shape_name}: ERROR {rec['error']}",
                  flush=True)
        else:
            print(f"[roofline] {arch} x {shape_name}: "
                  f"comp={rec['compute_s']*1e3:.2f}ms "
                  f"mem={rec['memory_s']*1e3:.2f}ms "
                  f"coll={rec['collective_s']*1e3:.2f}ms "
                  f"dom={rec['dominant']} "
                  f"frac={rec['roofline_fraction']:.2f} "
                  f"useful={rec['useful_flops_ratio']:.2f}", flush=True)


if __name__ == "__main__":
    main()
