"""Shared benchmark helpers: hardware, plans, CSV emission."""
from __future__ import annotations

import functools
import sys
import time

from repro.configs.paper_suite import WORKLOAD_CLASSES, paper_models
from repro.core import cost_model as cm
from repro.serving import build_paper_plans, poisson_workload

HW = cm.CPU_3990X
N_QUERIES = 400
SEED = 1

rows: list[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    line = f"{name},{us_per_call:.2f},{derived}"
    rows.append(line)
    print(line, flush=True)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


@functools.lru_cache(maxsize=None)
def plans_for(*models: str):
    return build_paper_plans(list(models), HW)


def class_workload(cls: str, qps: float, n: int = N_QUERIES,
                   seed: int = SEED):
    pm = paper_models()
    models = list(WORKLOAD_CLASSES[cls])
    weights = [1.0 / pm[m].qos_ms for m in models]
    return models, poisson_workload(models, qps, n, seed=seed,
                                    weights=weights)


QPS_GRIDS = {
    "light": (100, 200, 300, 450, 600),
    "medium": (80, 120, 160, 200, 240),
    "heavy": (3, 5, 8, 11, 14),
    "mix": (60, 100, 140, 180, 220),
}
