"""Online-serving benchmark: the real JAX engine with the VELTAIR policy
in the loop (repro.serving.runtime).

Sections:
  * online/<policy>_step_us        mean engine decode-step wall time while
                                   serving the mix under that policy
  * online/<policy>_qos            QoS rate of the replay (derived column)
  * online/switch_step_cold_us     set_interference_level + one decode step
                                   on the FIRST visit of each level: pays
                                   the trace/compile of that code version
                                   (in the default "xla" dispatch mode all
                                   versions share one executable, so this
                                   is the single first-trace stall; under
                                   "interpret"/"pallas" every distinct
                                   tile table pays it)
  * online/switch_step_warm_us     same after engine.warmup(): every switch
                                   is a version-cache hit — a dictionary
                                   swap of precompiled executables
  * colocate/<policy>_tick_us      mean cluster tick wall time while three
                                   *different* real models (gemma-2b,
                                   starcoder2-3b, mamba2-780m) share the
                                   unit pool under that policy; derived
                                   column reports QoS rate, per-engine mean
                                   levels, re-plan quanta and peak units —
                                   the VELTAIR-vs-baselines co-location
                                   comparison on the real engine path
  * quantum/<mode>_tok_s           warm decode throughput of the SAME
                                   workload through the per-step dispatch
                                   loop (one host sync per token) vs the
                                   fused quantum path (one executable and
                                   one sync per layer-block quantum);
                                   derived column reports p50/p99 latency,
                                   host syncs per token and tokens per
                                   sync — the numbers also land in
                                   BENCH_serving.json at the repo root,
                                   which tools/check_bench.py gates in CI
  * prefill/<mode>_tok_s           mixed-length (prompt_len_spread) warm
                                   serve throughput: chunked+bucketed
                                   prefill quanta vs monolithic
                                   per-exact-length prefill; derived
                                   column reports mean TTFT, post-warmup
                                   jax traces (chunked must hold 0 — CI
                                   gated) and bucket-padding overhead
  * paged/<arm>_peak_concurrent    dense vs paged KV cache at an EQUAL
                                   device memory budget: peak concurrent
                                   requests, peak resident tokens, cache
                                   utilization and post-warmup traces per
                                   arm; derived rows report the paged
                                   concurrency gain (CI gate: >= 1.5x at
                                   token-identical outputs), the counted
                                   shed/defer response of admission to
                                   page-pool exhaustion, and >= 1 page
                                   deduplicated by cross-request prefix
                                   sharing in a 2-tenant paged cluster
  * measured/proxy_rms_ratio       closing the adaptive-compilation loop:
                                   sliding-window RMS residual of the
                                   pressure proxy while serving on
                                   MEASURED per-quantum wall-time counters
                                   (engine CounterBank + online RLS
                                   re-fit), as a ratio over the
                                   oracle-calibration residual — CI gates
                                   it <= 1.5x
  * measured/ladder_gain_x         qps_at_qos of an engine running the
                                   autotuned tile ladder
                                   (tools/autotune_ladder.py ->
                                   search_tile_ladder) over the fixed
                                   DEFAULT_LEVEL_TILES table on the same
                                   virtual-time workload (CI gates >= 1x
                                   exact, plus zero post-warmup retraces
                                   on the ladder arm)
  * spec/<workload>_speedup_x      speculative decode quanta (n-gram
                                   draft -> one batched verify forward ->
                                   rollback) vs plain fused quanta, warm
                                   wall-clock tokens/s: the repetitive
                                   arm (plateaued continuations, drafter
                                   keeps hitting) is CI-gated >= 1.3x
                                   with token-identical streams and zero
                                   post-warmup retraces; the adversarial
                                   arm (short fresh-prompt decodes, few
                                   draft hits) is gated >= 0.95x — the
                                   draft+fallback overhead must stay in
                                   the noise
  * slo/<sched>_qps_at_qos         the headline metric: queries served
                                   UNDER their SLO deadline per second,
                                   on a bursty (Gamma-modulated Poisson)
                                   overload with three QoS tiers —
                                   FIFO-alternation vs SLO-tiered EDF
                                   scheduling with admission control at
                                   equal offered load.  Virtual-time
                                   serve: deterministic per seed, so the
                                   CI gate (slo >= 1.3x fifo, strict
                                   interactive >= standard >= batch tier
                                   ordering, token-identical outputs) is
                                   exact, not noise-tolerant; a third
                                   slo_spec arm serves the same stream
                                   with speculative quanta on and must
                                   keep the >= 1.3x-over-fifo win and
                                   token identity with the plain slo arm

Run ``python -m benchmarks.bench_online_serving --tiny`` for the
CI-sized run: the quantum section only, with a small workload, still
producing BENCH_serving.json.
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import numpy as np

from benchmarks.common import HW, emit
from repro.core.scheduler import (FixedBlockPolicy, ModelWisePolicy,
                                  PremaPolicy, VeltairPolicy)
from repro.serving import (AdmissionController, ClusterRuntime,
                           OnlineRuntime, Workload, build_cluster,
                           build_paper_plans, cluster_plans,
                           engine_version_sets)

TENANTS = ["resnet50", "googlenet"]
N_QUERIES = 24
CLUSTER_ARCHS = ["gemma-2b", "starcoder2-3b", "mamba2-780m"]
SLO_TENANTS = ["resnet50", "googlenet", "mobilenet_v2"]
SLO_TIERS = {"resnet50": "interactive", "googlenet": "standard",
             "mobilenet_v2": "batch"}
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_serving.json"


def _engine(plans, *, batch_slots=2, max_len=32, use_version_sets=True,
            **kw):
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    vs = engine_version_sets(plans) if use_version_sets else None
    return ServingEngine(cfg, params, batch_slots=batch_slots,
                         max_len=max_len, version_sets=vs, **kw)


def online_policies(plans):
    wl = Workload.poisson(TENANTS, 60, N_QUERIES, prompt_len=4,
                          max_new_tokens=4, seed=1)
    for name, policy in (("veltair", VeltairPolicy(HW)),
                         ("model_wise", ModelWisePolicy(HW))):
        engine = _engine(plans)
        engine.warmup(prompt_lens=(wl.prompt_len,))
        runtime = OnlineRuntime(engine, policy, plans, HW)
        t0 = time.time()
        m = runtime.serve(wl)
        wall = time.time() - t0
        emit(f"online/{name}_step_us",
             wall * 1e6 / max(runtime.steps, 1),
             f"qos={m.qos_rate:.2f};switches={engine.level_switches};"
             f"compile_ms={1e3 * runtime.compile_time_s:.2f}")


def level_switch_cost(plans):
    """Switch-then-step latency, first visit vs post-warmup: the stall the
    precompiled version cache removes from level switches."""
    import numpy as np

    from repro.core import cost_model as cm
    from repro.serving.engine import Request

    def _flip_times(engine, levels):
        rng = np.random.default_rng(0)
        req = Request(rid=0, prompt=rng.integers(
            0, engine.cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=10 * len(levels))
        engine.admit_request(req, drain=True)
        times = []
        for lv in levels:
            t0 = time.time()
            engine.set_interference_level(lv)
            engine.step()
            times.append(time.time() - t0)
        return times

    grid = [cm.grid_point(i) for i in range(cm.NUM_LEVELS)]
    cold = _flip_times(_engine(plans), grid)        # first visit per level
    warm_engine = _engine(plans)
    warm_engine.warmup(prompt_lens=(4,))
    warm = _flip_times(warm_engine, grid)
    emit("online/switch_step_cold_us", 1e6 * sum(cold) / len(cold),
         f"max_us={1e6 * max(cold):.0f}")
    emit("online/switch_step_warm_us", 1e6 * sum(warm) / len(warm),
         f"max_us={1e6 * max(warm):.0f};"
         f"cache={warm_engine.version_cache.stats}")


def colocation_policies():
    """Three heterogeneous real engines on one unit pool, side-by-side
    ServingMetrics for VELTAIR vs two-plus baselines (the ISSUE-3
    acceptance scenario).  Per-engine level traces come back in
    ClusterMetrics; the derived column compresses them to means."""
    plans = cluster_plans(CLUSTER_ARCHS, HW)
    wl = Workload.poisson(CLUSTER_ARCHS, 90, 18, prompt_len=4,
                          max_new_tokens=3, seed=1)
    policies = (("veltair", lambda: VeltairPolicy(HW)),
                ("model_wise", lambda: ModelWisePolicy(HW)),
                ("prema", lambda: PremaPolicy(HW)),
                ("block6", lambda: FixedBlockPolicy(HW, 6)))
    for name, pf in policies:
        tenants = build_cluster(CLUSTER_ARCHS, HW, plans=plans)
        runtime = ClusterRuntime(tenants, pf(), HW)
        runtime.warmup(prompt_lens=(wl.prompt_len,))
        t0 = time.time()
        m = runtime.serve(wl)
        wall = time.time() - t0
        levels = ";".join(f"{a.split('-')[0]}_lv={v:.2f}"
                          for a, v in m.mean_levels.items())
        emit(f"colocate/{name}_tick_us",
             wall * 1e6 / max(runtime.ticks, 1),
             f"qos={m.aggregate.qos_rate:.2f};"
             f"p99_ms={1e3 * m.aggregate.p99_latency_s:.2f};"
             f"quanta={sum(m.quanta.values())};"
             f"peak_units={m.pool_peak_used};{levels}")


def quantum_dispatch(plans, *, n_queries: int = N_QUERIES,
                     repeats: int = 3) -> dict:
    """Fused dispatch quanta vs the per-step loop on identical traffic.

    Both engines are fully warmed (level table + K-buckets + the
    admission row-writer via a throwaway warm request), so the measured
    gap is pure dispatch granularity: Python call + device->host sync per
    token vs one fused executable + one sync per quantum.  Each arm is
    measured ``repeats`` times and the best run kept (best-of filters
    transient machine load — the CI gate compares these numbers, so they
    must reflect the dispatch path, not a noisy neighbor).  Returns the
    machine-readable section written to BENCH_serving.json."""
    from repro.serving.engine import Request

    wl = Workload.poisson(TENANTS, 60, n_queries, prompt_len=4,
                          max_new_tokens=8, seed=1)
    arms = (("per_step", False), ("fused", True))
    engines: dict = {}
    for name, fused in arms:
        engine = _engine(plans)
        # the per-step arm never dispatches a fused quantum: skip its
        # (dead-weight) K-bucket AOT builds
        engine.warmup(prompt_lens=(wl.prompt_len,),
                      quantum_buckets=None if fused else ())
        # warm the admission path too (row-writer jit + prefill argmax)
        rng = np.random.default_rng(0)
        warm = Request(rid=-1, prompt=rng.integers(
            0, engine.cfg.vocab_size, wl.prompt_len).astype(np.int32),
            max_new_tokens=2)
        engine.run_to_completion([warm])
        engines[name] = engine

    def measure(name: str, fused: bool) -> dict:
        engine = engines[name]
        toks0, syncs0 = engine.tokens_decoded, engine.host_syncs
        runtime = OnlineRuntime(engine, VeltairPolicy(HW), plans, HW,
                                wall_clock=True, fused=fused)
        t0 = time.time()
        m = runtime.serve(wl)
        wall = time.time() - t0
        toks = engine.tokens_decoded - toks0
        syncs = engine.host_syncs - syncs0
        lats = np.array([r.latency for r in runtime.records])
        return {
            "tokens": int(toks),
            "wall_s": round(wall, 4),
            "tokens_per_s": round(toks / max(wall, 1e-9), 1),
            "host_syncs": int(syncs),
            "syncs_per_token": round(syncs / max(toks, 1), 4),
            "tokens_per_sync": round(toks / max(syncs, 1), 2),
            "p50_latency_ms": round(1e3 * float(np.percentile(lats, 50)), 2),
            "p99_latency_ms": round(1e3 * float(np.percentile(lats, 99)), 2),
            "qos_rate": round(m.qos_rate, 3),
            "quanta": int(runtime.quanta),
        }

    # interleave the arms' repeats so a transient load spike on a shared
    # CI runner hits both arms, not every sample of one — best-of can't
    # filter noise that is correlated within an arm
    section: dict = {}
    for _ in range(max(repeats, 1)):
        for name, fused in arms:
            run = measure(name, fused)
            if name not in section or \
                    run["tokens_per_s"] > section[name]["tokens_per_s"]:
                section[name] = run
    for name, _ in arms:
        emit(f"quantum/{name}_tok_s", section[name]["tokens_per_s"],
             f"p50_ms={section[name]['p50_latency_ms']};"
             f"p99_ms={section[name]['p99_latency_ms']};"
             f"syncs_per_tok={section[name]['syncs_per_token']};"
             f"tok_per_sync={section[name]['tokens_per_sync']}")
    section["speedup_tokens_per_s"] = round(
        section["fused"]["tokens_per_s"]
        / max(section["per_step"]["tokens_per_s"], 1e-9), 2)
    emit("quantum/fused_speedup_x", section["speedup_tokens_per_s"],
         "fused vs per-step warm decode throughput")
    return section


def prefill_dispatch(plans, *, n_queries: int = N_QUERIES) -> dict:
    """Mixed-length admission path: chunked+bucketed prefill quanta vs
    monolithic per-exact-length prefill on the same spread workload.

    Both arms warm up against the nominal prompt length (what a real
    deployment would have seen); the length spread then admits prompts
    the monolithic arm never compiled — every novel length is a
    mid-serving retrace stall, while the chunked arm serves everything
    from its power-of-two bucket table (``post_warmup_traces`` must stay
    0 — tools/check_bench.py gates it).  TTFT contrast: chunked prefill
    is metered as scheduled quanta, so ``avg_ttft_ms`` is real; the
    monolithic arm admits inside the dispatch loop where prefill is
    invisible to the clock — the understated-TTFT bug this section
    exists to keep fixed."""
    wl = Workload.poisson(TENANTS, 60, n_queries, prompt_len=14,
                          max_new_tokens=4, seed=3, prompt_len_spread=11)
    section: dict = {}
    for name, chunked in (("monolithic", False), ("chunked", True)):
        engine = _engine(plans, chunked_prefill=chunked,
                         prefill_chunk_len=8)
        engine.warmup(prompt_lens=(wl.prompt_len,))
        traces0 = engine.version_cache.traces
        runtime = OnlineRuntime(engine, VeltairPolicy(HW), plans, HW,
                                wall_clock=True)
        t0 = time.time()
        m = runtime.serve(wl)
        wall = time.time() - t0
        toks = engine.tokens_decoded
        section[name] = {
            "tokens": int(toks),
            "wall_s": round(wall, 4),
            "tokens_per_s": round(toks / max(wall, 1e-9), 1),
            "avg_ttft_ms": round(1e3 * m.avg_ttft_s, 3),
            "post_warmup_traces": int(engine.version_cache.traces
                                      - traces0),
            "prefill_tokens": int(engine.prefill_tokens),
            "pad_tokens": int(engine.prefill_pad_tokens),
            "qos_rate": round(m.qos_rate, 3),
        }
        emit(f"prefill/{name}_tok_s", section[name]["tokens_per_s"],
             f"ttft_ms={section[name]['avg_ttft_ms']};"
             f"traces={section[name]['post_warmup_traces']};"
             f"pad_tokens={section[name]['pad_tokens']}")
    return section


def slo_scheduling(*, n_queries: int = 48, qps: float = 900.0) -> dict:
    """Queries served under QoS: FIFO vs SLO-tiered EDF + admission
    control on one bursty overloaded tier mix (the paper's headline
    framing — queries that MAKE their deadline per second, not raw
    throughput).

    Both arms replay the identical Gamma-modulated arrival stream at the
    same offered load through identically-built engines; only the
    scheduler differs.  The serve runs in virtual time (wall_clock=False)
    so the comparison is deterministic per seed — no warmup needed: JAX
    compile stalls land in ``compile_time_s``, never in virtual latency.
    The SLO arm may shed hopeless sheddable queries (counted, and its
    records shrink accordingly); the gate compares satisfied queries per
    second and checks the two schedules stayed token-identical on every
    request both actually served."""
    plans = build_paper_plans(SLO_TENANTS, HW)
    wl = Workload.bursty(SLO_TENANTS, qps, n_queries, burstiness=4.0,
                         prompt_len=6, max_new_tokens=4, seed=7,
                         tiers=SLO_TIERS)
    section: dict = {"offered_qps": round(wl.qps, 1),
                     "n_queries": wl.n_queries,
                     "tiers": dict(SLO_TIERS)}
    outputs: dict[str, dict] = {}
    # the slo_spec arm serves the identical stream through the SLO
    # scheduler with speculative decode quanta on: speculation must
    # compose with EDF/admission (expected-accept slack scaling) and
    # hold the slo arm's queries-under-QoS — gated exact (virtual time)
    for name in ("fifo", "slo", "slo_spec"):
        engine = _engine(plans, speculative=name == "slo_spec")
        runtime = OnlineRuntime(
            engine, VeltairPolicy(HW), plans, HW,
            scheduler="slo" if name == "slo_spec" else name,
            admission=AdmissionController() if name != "fifo" else None)
        t0 = time.time()
        m = runtime.serve(wl)
        wall = time.time() - t0
        outputs[name] = runtime.outputs
        section[name] = {
            "qps_at_qos": round(m.qps_at_qos, 1),
            "qos_rate": round(m.qos_rate, 3),
            "served": int(m.n_queries),
            "satisfied": int(round(m.qos_rate * m.n_queries)),
            "shed": int(m.shed_queries),
            "deferred": int(m.deferred_queries),
            "wall_s": round(wall, 4),
            "per_tier_qos_rate": {
                t: round(tm.qos_rate, 3) for t, tm in m.per_tier.items()},
        }
        if name == "slo_spec":
            section[name]["spec_quanta"] = engine.spec_quanta
            section[name]["draft_hit_rate"] = round(
                engine.draft_hit_rate, 3)
        tiers = ";".join(f"{t}={v}" for t, v in
                         section[name]["per_tier_qos_rate"].items())
        emit(f"slo/{name}_qps_at_qos", section[name]["qps_at_qos"],
             f"qos={section[name]['qos_rate']};"
             f"shed={section[name]['shed']};"
             f"deferred={section[name]['deferred']};{tiers}")
    common = set(outputs["fifo"]) & set(outputs["slo"])
    section["token_identical"] = bool(common) and all(
        outputs["fifo"][rid] == outputs["slo"][rid] for rid in common)
    section["common_requests"] = len(common)
    section["gain_qps_at_qos"] = round(
        section["slo"]["qps_at_qos"]
        / max(section["fifo"]["qps_at_qos"], 1e-9), 2)
    emit("slo/gain_x", section["gain_qps_at_qos"],
         f"token_identical={section['token_identical']};"
         f"common={len(common)}")
    spec_common = set(outputs["slo"]) & set(outputs["slo_spec"])
    section["spec_token_identical"] = bool(spec_common) and all(
        outputs["slo"][rid] == outputs["slo_spec"][rid]
        for rid in spec_common)
    section["spec_gain_qps_at_qos"] = round(
        section["slo_spec"]["qps_at_qos"]
        / max(section["slo"]["qps_at_qos"], 1e-9), 2)
    emit("slo/spec_gain_x", section["spec_gain_qps_at_qos"],
         f"spec_quanta={section['slo_spec']['spec_quanta']};"
         f"hit={section['slo_spec']['draft_hit_rate']};"
         f"token_identical={section['spec_token_identical']}")
    return section


def paged_serving(plans, *, n_queries: int = 20) -> dict:
    """Memory as a scheduling dimension: dense vs paged KV residency at
    an EQUAL device memory budget.

    Dense row allocation pins ``batch_slots * max_len`` tokens of KV the
    moment an engine is built, so an M-token budget caps concurrency at
    ``M // max_len`` slots no matter how short the resident requests
    are.  The paged engine draws ``page_size``-token pages on demand
    from the same M-token pool, admits by worst-case page *commitment*,
    and deduplicates common prompt prefixes across requests — so the
    identical workload runs at higher peak concurrency on the same
    memory.  Both arms serve in virtual time (deterministic per seed),
    so the CI gates are exact: >= 1.5x peak concurrent requests,
    token-identical per-request outputs, zero post-warmup retraces on
    the paged arm, a *counted* admission response to page-pool
    exhaustion (tiny-pool arm), and >= 1 page shared via the prefix
    index in a two-tenant paged cluster."""
    max_len, page = 32, 8
    budget = 2 * max_len                # device KV budget, in tokens
    wl = Workload.bursty(TENANTS, 400.0, n_queries, prompt_len=8,
                         max_new_tokens=3, seed=5, prompt_len_spread=3,
                         shared_prefix_len=page)
    section: dict = {"memory_budget_tokens": budget, "max_len": max_len,
                     "n_queries": wl.n_queries}
    outputs: dict[str, dict] = {}
    arms = (("dense", dict(batch_slots=budget // max_len)),
            ("paged", dict(batch_slots=6, page_size=page,
                           n_pages=budget // page)))
    for name, kw in arms:
        engine = _engine(plans, max_len=max_len, **kw)
        engine.warmup(prompt_lens=(wl.prompt_len,))
        traces0 = engine.version_cache.traces
        runtime = OnlineRuntime(engine, VeltairPolicy(HW), plans, HW)
        t0 = time.time()
        m = runtime.serve(wl)
        wall = time.time() - t0
        outputs[name] = runtime.outputs
        arm = {
            "batch_slots": engine.slots,
            "peak_concurrent": int(engine.peak_active_slots),
            "peak_resident_tokens": int(
                engine.pool.peak_used * engine.page_size if engine.paged
                else engine.slots * engine.max_len),
            "peak_cache_tokens": int(m.peak_cache_tokens),
            "cache_utilization": round(m.cache_utilization, 3),
            "post_warmup_traces": int(engine.version_cache.traces
                                      - traces0),
            "qos_rate": round(m.qos_rate, 3),
            "wall_s": round(wall, 4),
        }
        if engine.paged:
            arm["page_stats"] = engine.page_stats
        section[name] = arm
        emit(f"paged/{name}_peak_concurrent", arm["peak_concurrent"],
             f"resident_tok={arm['peak_resident_tokens']};"
             f"peak_cache_tok={arm['peak_cache_tokens']};"
             f"util={arm['cache_utilization']};"
             f"traces={arm['post_warmup_traces']}")
    section["token_identical"] = outputs["dense"] == outputs["paged"]
    section["concurrency_gain"] = round(
        section["paged"]["peak_concurrent"]
        / max(section["dense"]["peak_concurrent"], 1), 2)
    emit("paged/concurrency_gain_x", section["concurrency_gain"],
         f"token_identical={section['token_identical']};"
         f"shared_hits={section['paged']['page_stats']['shared_hits']};"
         f"budget_tok={budget}")

    # admission control must respond to page-pool exhaustion: a pool too
    # small for the workload's worst-case commitments defers (counted)
    # instead of stalling silently or corrupting resident rows
    tiny = _engine(plans, max_len=max_len, batch_slots=4, page_size=page,
                   n_pages=3)
    tiny.warmup(prompt_lens=(wl.prompt_len,))
    runtime = OnlineRuntime(tiny, VeltairPolicy(HW), plans, HW,
                            admission=AdmissionController())
    twl = Workload.bursty(TENANTS, 400.0, n_queries, prompt_len=8,
                          max_new_tokens=3, seed=5, prompt_len_spread=3,
                          shared_prefix_len=page,
                          tiers={t: "standard" for t in TENANTS})
    tm = runtime.serve(twl)
    section["tiny_pool"] = {
        "n_pages": 3,
        "shed": int(tm.shed_queries),
        "deferred": int(tm.deferred_queries),
        "conflicts": int(tiny.page_stats["conflicts"]),
        "served": int(tm.n_queries),
    }
    emit("paged/tiny_pool_deferred", tm.deferred_queries,
         f"shed={tm.shed_queries};"
         f"conflicts={tiny.page_stats['conflicts']}")

    # cross-tenant prefix sharing on the cluster path: each tenant's
    # prompts carry a common prefix (ClusterRuntime.tenant_prompts), so
    # temporally-overlapping requests must deduplicate resident pages
    archs = CLUSTER_ARCHS[:2]
    tenants = build_cluster(archs, HW, batch_slots=2, max_len=max_len,
                            page_size=page)
    cluster = ClusterRuntime(tenants, VeltairPolicy(HW), HW,
                             admission=AdmissionController())
    cluster.warmup(prompt_lens=(12,))
    cwl = Workload.bursty(archs, 200.0, 16, prompt_len=12,
                          max_new_tokens=4, seed=5, shared_prefix_len=10,
                          tiers={archs[0]: "interactive",
                                 archs[1]: "batch"})
    cmx = cluster.serve(cwl)
    shared = sum(s.get("shared_hits", 0) for s in cmx.page_stats.values())
    section["cluster"] = {
        "tenants": list(archs),
        "shared_hits": int(shared),
        "cow_copies": int(sum(s.get("cow_copies", 0)
                              for s in cmx.page_stats.values())),
        "cache_utilization": round(cmx.aggregate.cache_utilization, 3),
        "page_stats": cmx.page_stats,
    }
    emit("paged/cluster_shared_hits", shared,
         f"cow={section['cluster']['cow_copies']};"
         f"util={section['cluster']['cache_utilization']}")
    return section


def measured_loop(plans, *, n_queries: int = N_QUERIES) -> dict:
    """Closing the adaptive-compilation loop: serve on MEASURED counters
    (the engine's per-quantum wall-time bank) with the online RLS proxy
    re-fit in the loop, and run the autotuned tile ladder against the
    fixed ``DEFAULT_LEVEL_TILES`` table.

    Arm 1 (proxy): one bursty serve with ``counter_source="measured"``.
    While the bank is cold the runtime falls back to oracle-synthesized
    samples (counted in ``counter_sources``); once warm, samples are
    re-expressed from measured slowdowns and every poll feeds the RLS
    window.  The reported residual is the proxy's sliding-window RMS at
    serve end, gated as a ratio over the offline calibration residual.

    Arm 2 (ladder): two identically-warmed engines WITHOUT version_sets
    — one on the hand-written level table, one on the
    ``search_tile_ladder`` artifact — replay the same workload in
    virtual time, so the qps_at_qos comparison is exact, and the ladder
    arm must finish its level sweep with zero post-warmup retraces."""
    from benchmarks.hillclimb import search_tile_ladder
    from repro.configs.paper_suite import paper_models
    from repro.core.interference import calibrate_proxy

    section: dict = {}

    # -- arm 1: synthesized-vs-measured proxy error -----------------------
    proxy = calibrate_proxy(HW)[0]
    oracle_rms = float(proxy.base_rms)
    wl = Workload.bursty(TENANTS, 300.0, n_queries, prompt_len=6,
                         max_new_tokens=4, seed=9)
    engine = _engine(plans)
    engine.warmup(prompt_lens=(wl.prompt_len,))
    runtime = OnlineRuntime(engine, VeltairPolicy(HW, proxy=proxy), plans,
                            HW, counter_source="measured")
    t0 = time.time()
    m = runtime.serve(wl)
    wall = time.time() - t0
    measured_rms = float(m.proxy_rms_error)
    section["proxy"] = {
        "oracle_rms": round(oracle_rms, 5),
        "measured_rms": round(measured_rms, 5),
        "rms_ratio": round(measured_rms / max(oracle_rms, 1e-9), 3),
        "refits": int(m.refit_count),
        "rls_updates": int(proxy.rls_updates),
        "polls": {k: int(v) for k, v in runtime.counter_sources.items()},
        "bank_observations": int(engine.counter_bank.observations),
        "qos_rate": round(m.qos_rate, 3),
        "wall_s": round(wall, 4),
    }
    emit("measured/proxy_rms_ratio", section["proxy"]["rms_ratio"],
         f"oracle_rms={section['proxy']['oracle_rms']};"
         f"measured_rms={section['proxy']['measured_rms']};"
         f"refits={section['proxy']['refits']};"
         f"polls={section['proxy']['polls']}")

    # -- arm 2: autotuned ladder vs fixed level table ---------------------
    pm = paper_models()["resnet50"]
    layer = max(pm.layers, key=lambda l: l.flops)
    spec = search_tile_ladder(layer, HW)
    lwl = Workload.bursty(TENANTS, 300.0, n_queries, prompt_len=6,
                          max_new_tokens=4, seed=11)
    section["ladder"] = {"spec_name": spec.name,
                         "distinct_tables": len(spec.tile_tables())}
    for name, kw in (("fixed", {}), ("autotuned", {"ladder": spec})):
        eng = _engine(plans, use_version_sets=False, **kw)
        eng.warmup(prompt_lens=(lwl.prompt_len,))
        traces0 = eng.version_cache.traces
        rt = OnlineRuntime(eng, VeltairPolicy(HW), plans, HW)
        t0 = time.time()
        lm = rt.serve(lwl)
        wall = time.time() - t0
        section["ladder"][name] = {
            "qps_at_qos": round(lm.qps_at_qos, 1),
            "qos_rate": round(lm.qos_rate, 3),
            "served": int(lm.n_queries),
            "post_warmup_traces": int(eng.version_cache.traces - traces0),
            "level_switches": int(eng.level_switches),
            "wall_s": round(wall, 4),
        }
    section["ladder"]["gain_qps_at_qos"] = round(
        section["ladder"]["autotuned"]["qps_at_qos"]
        / max(section["ladder"]["fixed"]["qps_at_qos"], 1e-9), 3)
    emit("measured/ladder_gain_x", section["ladder"]["gain_qps_at_qos"],
         f"fixed={section['ladder']['fixed']['qps_at_qos']};"
         f"autotuned={section['ladder']['autotuned']['qps_at_qos']};"
         f"traces={section['ladder']['autotuned']['post_warmup_traces']};"
         f"tables={section['ladder']['distinct_tables']}")
    return section


def speculative_decode(plans, *, n_new: int = 160, max_len: int = 256,
                       k: int = 8, depth: int = 4, reps: int = 3) -> dict:
    """Speculative decode quanta (draft -> batched verify -> rollback)
    vs plain fused quanta, on two workload shapes.

    The *repetitive* arm decodes long plateaued continuations (templated
    text is the serving-world analogue) where the prompt-lookup drafter
    keeps hitting: speculation must convert the predictability into a
    real wall-clock win (CI gates >= 1.3x tokens/s) while staying
    token-identical and holding zero post-warmup retraces — warmup
    prebuilds the spec verify executables alongside the K-buckets.  The
    *adversarial* arm serves short fresh-prompt decodes where drafts
    rarely land or the drafter abstains entirely: the cost of drafting +
    fallback must stay within noise of the plain path (CI gates >=
    0.95x).  Both arms are best-of-``reps`` wall-clock, interleaved like
    the quantum section so correlated load spikes hit both."""
    from repro.serving.engine import Request

    def build(spec: bool) -> object:
        eng = _engine(plans, max_len=max_len, speculative=spec,
                      spec_depth=depth)
        eng.warmup(prompt_lens=(20, 19, 8, 7, 6))
        return eng

    def serve(eng, prompts, n_tokens) -> tuple[float, list, int]:
        reqs = [Request(rid=i, prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(zip(prompts, n_tokens))]
        pending = list(reqs)
        while pending and eng.admit_request(pending[0], drain=True):
            pending.pop(0)
        t0 = time.time()
        while pending or not all(r.done for r in reqs):
            eng.step_quantum(k)
            while pending and eng.admit_request(pending[0], drain=True):
                pending.pop(0)
        wall = time.time() - t0
        toks = sum(len(r.output) for r in reqs)
        return wall, [list(r.output) for r in reqs], toks

    # repetitive: constant-token prompts collapse greedy decode onto a
    # plateau the n-gram drafter tracks almost perfectly; adversarial:
    # fresh random prompts, decodes too short for any plateau to form
    rng = np.random.default_rng(7)
    rep_prompts = [np.full(20 - i, 7 + i, np.int32) for i in range(2)]
    adv_prompts = [rng.integers(0, 256, n).astype(np.int32)
                   for n in (8, 7, 6)]
    arms = {"repetitive": (rep_prompts, [n_new] * len(rep_prompts)),
            "adversarial": (adv_prompts, [12] * len(adv_prompts))}

    engines = {False: build(False), True: build(True)}
    section: dict = {"k": k, "depth": depth}
    outs: dict = {}
    for wl_name, (prompts, n_tokens) in arms.items():
        best: dict = {}
        for _ in range(max(reps, 1)):
            for spec in (False, True):
                eng = engines[spec]
                traces0 = eng.version_cache.traces
                s0 = dict(eng.spec_stats)
                wall, out, toks = serve(eng, prompts, n_tokens)
                outs[(wl_name, spec)] = out
                name = "spec" if spec else "plain"
                run = {
                    "tokens": toks,
                    "wall_s": round(wall, 4),
                    "tokens_per_s": round(toks / max(wall, 1e-9), 1),
                    "post_warmup_traces":
                        eng.version_cache.traces - traces0,
                }
                if spec:
                    s1 = eng.spec_stats
                    drafted = s1["tokens_drafted"] - s0["tokens_drafted"]
                    accepted = s1["tokens_accepted"] - s0["tokens_accepted"]
                    run.update(
                        spec_quanta=s1["spec_quanta"] - s0["spec_quanta"],
                        spec_fallbacks=(s1["spec_fallbacks"]
                                        - s0["spec_fallbacks"]),
                        spec_rollbacks=(s1["spec_rollbacks"]
                                        - s0["spec_rollbacks"]),
                        tokens_drafted=drafted,
                        tokens_accepted=accepted,
                        draft_hit_rate=round(accepted / max(drafted, 1), 3))
                if name not in best or \
                        run["tokens_per_s"] > best[name]["tokens_per_s"]:
                    best[name] = run
        best["token_identical"] = \
            outs[(wl_name, False)] == outs[(wl_name, True)]
        best["speedup_tokens_per_s"] = round(
            best["spec"]["tokens_per_s"]
            / max(best["plain"]["tokens_per_s"], 1e-9), 2)
        section[wl_name] = best
        emit(f"spec/{wl_name}_speedup_x", best["speedup_tokens_per_s"],
             f"plain={best['plain']['tokens_per_s']};"
             f"spec={best['spec']['tokens_per_s']};"
             f"hit={best['spec'].get('draft_hit_rate', 0)};"
             f"fallbacks={best['spec'].get('spec_fallbacks', 0)};"
             f"traces={best['spec']['post_warmup_traces']};"
             f"token_identical={best['token_identical']}")
    return section


def write_bench_json(quantum: dict, prefill: dict, slo: dict, paged: dict,
                     measured: dict, spec: dict, mode: str) -> None:
    BENCH_JSON.write_text(json.dumps(
        {"bench": "online_serving", "mode": mode, "quantum": quantum,
         "prefill": prefill, "slo": slo, "paged": paged,
         "measured": measured, "spec": spec},
        indent=2) + "\n")
    print(f"# wrote {BENCH_JSON}", flush=True)


def run_all():
    plans = build_paper_plans(TENANTS, HW)
    online_policies(plans)
    level_switch_cost(plans)
    colocation_policies()
    write_bench_json(quantum_dispatch(plans), prefill_dispatch(plans),
                     slo_scheduling(), paged_serving(plans),
                     measured_loop(plans), speculative_decode(plans),
                     "full")


def run_tiny():
    """CI-sized run: the quantum fused-vs-per-step comparison, the
    mixed-length prefill section, the SLO scheduling comparison, the
    paged-vs-dense memory comparison and the measured-counter loop (all
    CI-gated).  More repeats than the full run for the wall-clock
    quantum section — the CI gate compares those numbers on noisy shared
    runners, so best-of needs extra samples; the slo, paged and measured
    sections are virtual-time deterministic and need none."""
    plans = build_paper_plans(TENANTS, HW)
    write_bench_json(quantum_dispatch(plans, n_queries=16, repeats=5),
                     prefill_dispatch(plans, n_queries=12),
                     slo_scheduling(n_queries=36),
                     paged_serving(plans, n_queries=16),
                     measured_loop(plans, n_queries=16),
                     speculative_decode(plans, n_new=120, reps=3), "tiny")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run_tiny() if "--tiny" in sys.argv[1:] else run_all()
