"""Online-serving benchmark: the real JAX engine with the VELTAIR policy
in the loop (repro.serving.runtime).

Sections:
  * online/<policy>_step_us        mean engine decode-step wall time while
                                   serving the mix under that policy
  * online/<policy>_qos            QoS rate of the replay (derived column)
  * online/switch_step_cold_us     set_interference_level + one decode step
                                   on the FIRST visit of each level: pays
                                   the trace/compile of that code version
                                   (in the default "xla" dispatch mode all
                                   versions share one executable, so this
                                   is the single first-trace stall; under
                                   "interpret"/"pallas" every distinct
                                   tile table pays it)
  * online/switch_step_warm_us     same after engine.warmup(): every switch
                                   is a version-cache hit — a dictionary
                                   swap of precompiled executables
  * colocate/<policy>_tick_us      mean cluster tick wall time while three
                                   *different* real models (gemma-2b,
                                   starcoder2-3b, mamba2-780m) share the
                                   unit pool under that policy; derived
                                   column reports QoS rate, per-engine mean
                                   levels, re-plan quanta and peak units —
                                   the VELTAIR-vs-baselines co-location
                                   comparison on the real engine path
"""
from __future__ import annotations

import time

from benchmarks.common import HW, emit
from repro.core.scheduler import (FixedBlockPolicy, ModelWisePolicy,
                                  PremaPolicy, VeltairPolicy)
from repro.serving import (ClusterRuntime, OnlineRuntime, Workload,
                           build_cluster, build_paper_plans, cluster_plans,
                           engine_version_sets)

TENANTS = ["resnet50", "googlenet"]
N_QUERIES = 24
CLUSTER_ARCHS = ["gemma-2b", "starcoder2-3b", "mamba2-780m"]


def _engine(plans):
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, batch_slots=2, max_len=32,
                         version_sets=engine_version_sets(plans))


def online_policies(plans):
    wl = Workload.poisson(TENANTS, 60, N_QUERIES, prompt_len=4,
                          max_new_tokens=4, seed=1)
    for name, policy in (("veltair", VeltairPolicy(HW)),
                         ("model_wise", ModelWisePolicy(HW))):
        engine = _engine(plans)
        engine.warmup(prompt_lens=(wl.prompt_len,))
        runtime = OnlineRuntime(engine, policy, plans, HW)
        t0 = time.time()
        m = runtime.serve(wl)
        wall = time.time() - t0
        emit(f"online/{name}_step_us",
             wall * 1e6 / max(runtime.steps, 1),
             f"qos={m.qos_rate:.2f};switches={engine.level_switches};"
             f"compile_ms={1e3 * runtime.compile_time_s:.2f}")


def level_switch_cost(plans):
    """Switch-then-step latency, first visit vs post-warmup: the stall the
    precompiled version cache removes from level switches."""
    import numpy as np

    from repro.core import cost_model as cm
    from repro.serving.engine import Request

    def _flip_times(engine, levels):
        rng = np.random.default_rng(0)
        req = Request(rid=0, prompt=rng.integers(
            0, engine.cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=10 * len(levels))
        engine.add_request(req)
        times = []
        for lv in levels:
            t0 = time.time()
            engine.set_interference_level(lv)
            engine.step()
            times.append(time.time() - t0)
        return times

    grid = [cm.grid_point(i) for i in range(cm.NUM_LEVELS)]
    cold = _flip_times(_engine(plans), grid)        # first visit per level
    warm_engine = _engine(plans)
    warm_engine.warmup(prompt_lens=(4,))
    warm = _flip_times(warm_engine, grid)
    emit("online/switch_step_cold_us", 1e6 * sum(cold) / len(cold),
         f"max_us={1e6 * max(cold):.0f}")
    emit("online/switch_step_warm_us", 1e6 * sum(warm) / len(warm),
         f"max_us={1e6 * max(warm):.0f};"
         f"cache={warm_engine.version_cache.stats}")


def colocation_policies():
    """Three heterogeneous real engines on one unit pool, side-by-side
    ServingMetrics for VELTAIR vs two-plus baselines (the ISSUE-3
    acceptance scenario).  Per-engine level traces come back in
    ClusterMetrics; the derived column compresses them to means."""
    plans = cluster_plans(CLUSTER_ARCHS, HW)
    wl = Workload.poisson(CLUSTER_ARCHS, 90, 18, prompt_len=4,
                          max_new_tokens=3, seed=1)
    policies = (("veltair", lambda: VeltairPolicy(HW)),
                ("model_wise", lambda: ModelWisePolicy(HW)),
                ("prema", lambda: PremaPolicy(HW)),
                ("block6", lambda: FixedBlockPolicy(HW, 6)))
    for name, pf in policies:
        tenants = build_cluster(CLUSTER_ARCHS, HW, plans=plans)
        runtime = ClusterRuntime(tenants, pf(), HW)
        runtime.warmup(prompt_lens=(wl.prompt_len,))
        t0 = time.time()
        m = runtime.serve(wl)
        wall = time.time() - t0
        levels = ";".join(f"{a.split('-')[0]}_lv={v:.2f}"
                          for a, v in m.mean_levels.items())
        emit(f"colocate/{name}_tick_us",
             wall * 1e6 / max(runtime.ticks, 1),
             f"qos={m.aggregate.qos_rate:.2f};"
             f"p99_ms={1e3 * m.aggregate.p99_latency_s:.2f};"
             f"quanta={sum(m.quanta.values())};"
             f"peak_units={m.pool_peak_used};{levels}")


def run_all():
    plans = build_paper_plans(TENANTS, HW)
    online_policies(plans)
    level_switch_cost(plans)
    colocation_policies()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run_all()
