"""Online-serving benchmark: the real JAX engine with the VELTAIR policy
in the loop (repro.serving.runtime).

Sections:
  * online/<policy>_step_us      mean engine decode-step wall time while
                                 serving the mix under that policy
  * online/<policy>_qos          QoS rate of the replay (derived column)
  * online/level_switch_us       cost of set_interference_level when the
                                 level (and therefore the tile overrides)
                                 actually changes, xla dispatch mode
"""
from __future__ import annotations

import time

from benchmarks.common import HW, emit
from repro.core.scheduler import ModelWisePolicy, VeltairPolicy
from repro.serving import (OnlineRuntime, Workload, build_paper_plans,
                           engine_version_sets)

TENANTS = ["resnet50", "googlenet"]
N_QUERIES = 24


def _engine(plans):
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, batch_slots=2, max_len=32,
                         version_sets=engine_version_sets(plans))


def online_policies(plans):
    wl = Workload.poisson(TENANTS, 60, N_QUERIES, prompt_len=4,
                          max_new_tokens=4, seed=1)
    for name, policy in (("veltair", VeltairPolicy(HW)),
                         ("model_wise", ModelWisePolicy(HW))):
        engine = _engine(plans)
        runtime = OnlineRuntime(engine, policy, plans, HW)
        t0 = time.time()
        m = runtime.serve(wl)
        wall = time.time() - t0
        emit(f"online/{name}_step_us",
             wall * 1e6 / max(runtime.steps, 1),
             f"qos={m.qos_rate:.2f};switches={engine.level_switches}")


def level_switch_cost(plans):
    engine = _engine(plans)
    engine.set_interference_level(0.0)
    t0 = time.time()
    n = 200
    for i in range(n):
        engine.set_interference_level(float(i % 2))  # always a real switch
    emit("online/level_switch_us", (time.time() - t0) * 1e6 / n,
         f"switches={engine.level_switches}")


def run_all():
    plans = build_paper_plans(TENANTS, HW)
    online_policies(plans)
    level_switch_cost(plans)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run_all()
