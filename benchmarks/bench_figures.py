"""Paper-figure reproductions (one function per figure/table).

Each function prints ``name,us_per_call,derived`` CSV rows via
benchmarks.common.emit and returns a dict for EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (HW, QPS_GRIDS, class_workload, emit,
                               plans_for)
from repro.configs.paper_suite import paper_models, resnet50
from repro.core import cost_model as cm
from repro.core import schedule_space as ss
from repro.core.interference import calibrate_proxy, pca_variance
from repro.core.multiversion import compile_layer, extract_dominant
from repro.core.qos import qps_at_qos
from repro.core.scheduler import (FixedBlockPolicy, LayerWisePolicy,
                                  ModelWisePolicy, PremaPolicy,
                                  VeltairPolicy)
from repro.serving import Simulator, poisson_workload


def _run(plans, policy, wl):
    t0 = time.time()
    sim = Simulator(HW, plans, policy)
    m = sim.run(wl)
    return m, (time.time() - t0) * 1e6


# -- Fig. 3: scheduling granularity vs arrival rate -------------------------
def fig3_granularity():
    plans = plans_for("resnet50")
    out = {}
    for qps in (100, 150, 200, 250):
        wl = poisson_workload(["resnet50"], qps, 400, seed=1)
        for name, pf in [("model", ModelWisePolicy(HW)),
                         ("layer", LayerWisePolicy(HW)),
                         ("block6", FixedBlockPolicy(HW, 6)),
                         ("block11", FixedBlockPolicy(HW, 11)),
                         ("adaptive", VeltairPolicy(
                             HW, adaptive_compile=False))]:
            m, us = _run(plans, pf, wl)
            emit(f"fig3.{name}.qps{qps}", us,
                 f"qos_rate={m.qos_rate:.3f};lat_ms={m.avg_latency_s*1e3:.2f}")
            out[(name, qps)] = m
    return out


# -- Fig. 4: per-layer core scaling + allocation ----------------------------
def fig4_core_scaling():
    layers = resnet50()
    picks = [layers[1], layers[10], layers[30], layers[50]]
    out = {}
    for lay in picks:
        v = ss.default_version(lay, HW)
        base = cm.latency(HW, v, 1, cm.Interference())
        speed = {u: base / cm.latency(HW, v, u, cm.Interference())
                 for u in (1, 2, 4, 8, 16, 32, 64)}
        emit(f"fig4.scaling.{lay.name}", 0.0,
             ";".join(f"x{u}={s:.1f}" for u, s in speed.items()))
        out[lay.name] = speed
    plan = plans_for("resnet50")["resnet50"]
    emit("fig4.allocation", 0.0,
         f"model_wise={plan.fcfs_units};avg_layer={np.mean(plan.layer_units):.1f};"
         f"max_layer={max(plan.layer_units)};min_layer={min(plan.layer_units)}")
    return out


# -- Fig. 5: conflict rates + overhead ---------------------------------------
def fig5_conflicts():
    plans = plans_for("resnet50")
    out = {}
    for qps in (150, 250, 300):
        wl = poisson_workload(["resnet50"], qps, 400, seed=1)
        for name, pf in [("model", ModelWisePolicy(HW)),
                         ("layer", LayerWisePolicy(HW)),
                         ("block6", FixedBlockPolicy(HW, 6)),
                         ("adaptive", VeltairPolicy(
                             HW, adaptive_compile=False))]:
            m, us = _run(plans, pf, wl)
            emit(f"fig5.{name}.qps{qps}", us,
                 f"conflict_rate={m.conflict_rate:.3f}")
            out[(name, qps)] = m.conflict_rate
    emit("fig5.overhead", 0.0,
         f"per_conflict_us={HW.realloc_overhead_s*1e6:.0f} (paper: 220us mean)")
    return out


# -- Fig. 6: versions vs interference level ---------------------------------
def fig6_multiversion():
    from repro.configs.paper_suite import conv
    lay = conv("rn14", 14, 256, 256, k=3)
    vs = ss.enumerate_versions(lay, HW)
    units = 16
    grid = cm.level_grid()
    best0 = min(vs, key=lambda v: cm.latency(HW, v, units, grid[0]))
    best9 = min(vs, key=lambda v: cm.latency(HW, v, units, grid[-1]))
    mid = extract_dominant(vs)
    mid.sort(key=lambda v: -v.tile_bytes)
    # paper convention: impl-1 = zero-interference optimum (TVM default),
    # impl-4 = the interference-tolerant extreme
    four = [best0, mid[len(mid) // 3], mid[2 * len(mid) // 3], best9]
    rows = {}
    for i, v in enumerate(four, 1):
        lats = [cm.latency(HW, v, units, itf) * 1e6 for itf in grid]
        emit(f"fig6.impl{i}", lats[0],
             "lat_us=" + "/".join(f"{l:.0f}" for l in lats)
             + f";degradation={lats[-1]/lats[0]:.2f}x")
        rows[f"impl{i}"] = lats
    env = [min(r[j] for r in rows.values()) for j in range(len(grid))]
    emit("fig6.envelope", env[0],
         "lat_us=" + "/".join(f"{l:.0f}" for l in env))
    return rows


# -- Fig. 7 / 14bc: version-count sensitivity --------------------------------
def fig7_version_count():
    layers = resnet50()
    grid = cm.level_grid()
    units = 16
    loss_by_v: dict[int, list[float]] = {k: [] for k in (1, 2, 3, 5)}
    needed = []
    for lay in layers:
        dom = extract_dominant(ss.enumerate_versions(lay, HW))
        dom.sort(key=lambda v: v.tile_bytes)
        full_env = [min(cm.latency(HW, v, units, itf) for v in dom)
                    for itf in grid]
        for keep_n in loss_by_v:
            if len(dom) <= keep_n:
                sub = dom
            else:
                idx = sorted({round(i * (len(dom) - 1) / (keep_n - 1))
                              for i in range(keep_n)}) if keep_n > 1 else [
                    len(dom) - 1]
                sub = [dom[i] for i in idx]
            env = [min(cm.latency(HW, v, units, itf) for v in sub)
                   for itf in grid]
            loss_by_v[keep_n].append(
                max(e / f for e, f in zip(env, full_env)) - 1.0)
        vset = compile_layer(lay, HW, qos_budget_s=1e-3)
        needed.append(len(vset.versions))
    for k, losses in loss_by_v.items():
        emit(f"fig7.loss_with_{k}_versions", 0.0,
             f"mean_loss={np.mean(losses)*100:.1f}%;max={np.max(losses)*100:.1f}%")
    hist = np.bincount(needed, minlength=6)[1:6]
    emit("fig14c.version_count_hist", 0.0,
         ";".join(f"v{i+1}={c}" for i, c in enumerate(hist))
         + f";le3={(np.array(needed) <= 3).mean()*100:.0f}%")
    return {"loss_by_v": loss_by_v, "needed": needed}


# -- Fig. 11: interference proxy ---------------------------------------------
def fig11_proxy():
    proxy, counters, levels = calibrate_proxy(HW, n=512)
    var = pca_variance(counters)
    emit("fig11.pca", 0.0,
         "var=" + "/".join(f"{v*100:.1f}%" for v in var[:4]))
    emit("fig11.proxy_r2", 0.0, f"r2={proxy.r2:.3f}")
    return {"r2": proxy.r2, "pca": var}


# -- Fig. 12: QPS @ 95% QoS vs baselines -------------------------------------
def fig12_qps(quick: bool = False):
    out = {}
    classes = ("light", "medium", "heavy", "mix")
    pols = [("planaria", lambda: LayerWisePolicy(HW)),
            ("prema", lambda: PremaPolicy(HW)),
            ("veltair-as", lambda: VeltairPolicy(HW, adaptive_compile=False)),
            ("veltair-ac", lambda: VeltairPolicy(HW, adaptive_schedule=False)),
            ("veltair-full", lambda: VeltairPolicy(HW))]
    for cls in classes:
        grid = QPS_GRIDS[cls][:3] if quick else QPS_GRIDS[cls]
        models, _ = class_workload(cls, grid[0])
        plans = plans_for(*models)
        for name, pf in pols:
            sweep = []
            for qps in grid:
                _, wl = class_workload(cls, qps)
                m, us = _run(plans, pf(), wl)
                sweep.append((qps, m))
            best = qps_at_qos(sweep, 0.95)
            best90 = qps_at_qos(sweep, 0.90)
            out[(cls, name)] = (best, best90, sweep)
            emit(f"fig12.{cls}.{name}", 0.0,
                 f"qps_at_95={best:.0f};qps_at_90={best90:.0f};rates="
                 + "/".join(f"{m.qos_rate:.2f}" for _, m in sweep))
    for cls in classes:
        base = max(out[(cls, "planaria")][1], 1e-9)
        full = out[(cls, "veltair-full")][1]
        emit(f"fig12.{cls}.improvement", 0.0,
             f"full_vs_planaria={100*(full-base)/base:+.0f}% (@90% QoS)")
    return out


# -- Fig. 13: latency vs solo-run ---------------------------------------------
def fig13_latency(fig12_out):
    pm = paper_models()
    out = {}
    for cls in ("medium", "heavy"):
        models, _ = class_workload(cls, 1)
        plans = plans_for(*models)
        solo = {}
        for name, plan in plans.items():
            solo[name] = sum(
                cm.latency(HW, vs.solo_version(), HW.n_units,
                           cm.Interference())
                for vs in plan.version_sets)
        for pol in ("planaria", "veltair-as", "veltair-ac", "veltair-full"):
            qps95, _, sweep = fig12_out[(cls, pol)]
            # measure latency at the highest sustained point
            target = max(qps95, sweep[0][0])
            m = [mm for q, mm in sweep if q <= target][-1]
            ratio = m.avg_latency_s / np.mean(list(solo.values()))
            out[(cls, pol)] = ratio
            emit(f"fig13.{cls}.{pol}", 0.0,
                 f"lat_vs_solo={ratio:.2f}x")
    return out


# -- Fig. 14a: core-usage efficiency -------------------------------------------
def fig14_efficiency():
    plans = plans_for("resnet50")
    out = {}
    for qps, loadname in ((100, "40%"), (180, "75%")):
        wl = poisson_workload(["resnet50"], qps, 300, seed=1)
        res = {}
        for name, pf in [("layer", LayerWisePolicy(HW)),
                         ("model", ModelWisePolicy(HW)),
                         ("veltair", VeltairPolicy(
                             HW, adaptive_compile=False))]:
            m, _ = _run(plans, pf, wl)
            res[name] = m.unit_efficiency
        gap_v = (res["layer"] - res["veltair"]) / max(res["layer"], 1e-9)
        gap_m = (res["layer"] - res["model"]) / max(res["layer"], 1e-9)
        emit(f"fig14a.load{loadname}", 0.0,
             f"veltair_gap={gap_v*100:.0f}%;model_gap={gap_m*100:.0f}%"
             f" (paper: <10% vs 47%)")
        out[loadname] = (gap_v, gap_m)
    return out
