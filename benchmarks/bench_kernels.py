"""Kernel microbenchmarks: correctness vs oracle + interpret-mode timing.

Interpret-mode wall times are NOT TPU performance (the kernel body runs in
Python); the perf-relevant numbers are the structural ones — VMEM working
set per tile variant and arithmetic intensity — which feed the adaptive
compiler's version space.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref
from repro.kernels.block_matmul import vmem_bytes


def bench_matmul_variants():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    want = np.asarray(ref.matmul_ref(x, w))
    for bm, bk, bn in ((32, 64, 32), (64, 128, 64), (128, 256, 128)):
        t0 = time.time()
        got = ops.block_matmul(x, w, bm=bm, bk=bk, bn=bn, interpret=True)
        us = (time.time() - t0) * 1e6
        err = float(np.max(np.abs(np.asarray(got) - want)))
        flops = 2 * 256 * 512 * 256
        vmem = vmem_bytes(bm, bk, bn, 4)
        emit(f"kernel.matmul.{bm}x{bk}x{bn}", us,
             f"max_err={err:.2e};vmem_tile_bytes={vmem};"
             f"intensity={flops / max(vmem, 1):.1f}")


def bench_flash_attention():
    rng = np.random.default_rng(1)
    B, S, H, K, D = 2, 64, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(S), (B, S))
    want = np.asarray(ref.attention_ref(q, k, v, offset=0, kv_valid_len=S))
    for bq, bkv in ((16, 16), (32, 32)):
        t0 = time.time()
        got = ops.flash_attention(q, k, v, q_positions=qpos, kv_valid_len=S,
                                  bq=bq, bkv=bkv, interpret=True)
        us = (time.time() - t0) * 1e6
        err = float(np.max(np.abs(np.asarray(got) - want)))
        emit(f"kernel.flash.bq{bq}_bkv{bkv}", us, f"max_err={err:.2e}")


def bench_ssd():
    rng = np.random.default_rng(2)
    B, L, H, P, N = 2, 64, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    bmat = jnp.asarray(rng.standard_normal((B, L, H, N)), jnp.float32)
    cmat = jnp.asarray(rng.standard_normal((B, L, H, N)), jnp.float32)
    yref, sref = ref.ssd_ref(x, dt, a, bmat, cmat, chunk_size=8)
    for chunk in (8, 16, 32):
        t0 = time.time()
        y, s = ops.ssd_scan(x, dt, a, bmat, cmat, chunk_size=chunk,
                            interpret=True)
        us = (time.time() - t0) * 1e6
        err = float(np.max(np.abs(np.asarray(y) - np.asarray(yref))))
        emit(f"kernel.ssd.chunk{chunk}", us, f"max_err={err:.2e}")


def run_all():
    bench_matmul_variants()
    bench_flash_attention()
    bench_ssd()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run_all()
