"""Render the roofline/dry-run tables from results/*.jsonl (no compiles).

    PYTHONPATH=src:. python -m benchmarks.report
"""
import json
import os

RESULTS = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "results"))


def _load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path) if l.strip()]


def roofline_table():
    recs = [r for r in _load("roofline_cells.jsonl") if "error" not in r]
    if not recs:
        print("# no roofline records yet")
        return
    print("\n## Roofline table (16x16 mesh, per-device seconds)")
    print(f"{'arch':22s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
          f"{'mem_flr':>9s} {'coll_s':>9s} {'dom':>10s} {'frac':>5s} "
          f"{'useful':>6s}")
    for r in recs:
        print(f"{r['arch']:22s} {r['shape']:12s} "
              f"{r['compute_s']:9.3f} {r['memory_s']:9.3f} "
              f"{r['memory_floor_s']:9.3f} {r['collective_s']:9.3f} "
              f"{r['dominant']:>10s} {r['roofline_fraction']:5.2f} "
              f"{r['useful_flops_ratio']:6.2f}")
    doms = {}
    for r in recs:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"# bottleneck distribution: {doms} over {len(recs)} cells")


def dryrun_summary():
    for mesh in ("16x16", "2x16x16", "serve_v2"):
        recs = _load(f"dryrun_{mesh}.jsonl")
        if not recs:
            continue
        ok = [r for r in recs if r["status"] == "ok"]
        sk = [r for r in recs if r["status"] == "skipped"]
        er = [r for r in recs if r["status"] == "error"]
        print(f"\n## Dry-run @ {mesh}: {len(ok)} ok / {len(sk)} skipped / "
              f"{len(er)} errors")
        if ok:
            worst = max(ok, key=lambda r: r["memory"].get(
                "argument_size_in_bytes", 0))
            print(f"#   largest args/dev: {worst['arch']} x {worst['shape']}"
                  f" = {worst['memory']['argument_size_in_bytes']/2**30:.2f}"
                  f" GiB")
            colls = sum(sum(r["collectives"]["counts"].values()) for r in ok)
            print(f"#   total collective ops across cells: {colls}")


def hillclimb_log():
    recs = _load("hillclimb.jsonl")
    if not recs:
        return
    print("\n## Hillclimb measurements")
    for r in recs:
        print(f"{r['cell']:38s} {r['variant']:22s} "
              f"comp={r['compute_s']*1e3:9.2f}ms "
              f"mem={r['memory_s']*1e3:9.2f}ms "
              f"coll={r['collective_s']*1e3:9.2f}ms dom={r['dominant']}")


def main():
    dryrun_summary()
    roofline_table()
    hillclimb_log()


if __name__ == "__main__":
    main()
