"""Serving engine: batched continuous decode == manual decode loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def _manual_greedy(model, params, prompt, n_new, max_len):
    cache = model.init_cache(1, max_len)
    logits, cache = model.prefill(params, {"tokens": prompt[None]}, cache)
    out = [int(jnp.argmax(logits[0]))]
    t = prompt.shape[0]
    for _ in range(n_new):
        logits, cache = model.decode_step(
            params, {"tokens": jnp.asarray([out[-1]], jnp.int32)}, cache,
            jnp.int32(t))
        out.append(int(jnp.argmax(logits[0])))
        t += 1
    return out


def test_engine_matches_manual_decode():
    cfg = get_reduced_config("starcoder2-3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, 10), jnp.int32)
    n_new = 5
    want = _manual_greedy(model, params, prompt, n_new, 32)

    engine = ServingEngine(cfg, params, batch_slots=2, max_len=32)
    req = Request(rid=0, prompt=np.asarray(prompt), max_new_tokens=n_new)
    done = engine.run_to_completion([req])
    assert len(done) == 1
    got = done[0].output[:n_new + 1]
    assert got == want[:len(got)], (got, want)


def test_engine_serves_multiple_requests():
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4)
            for i in range(5)]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=24)
    done = engine.run_to_completion(reqs)
    assert len(done) == 5
    assert all(len(r.output) >= 5 for r in done)
