"""Online runtime: VELTAIR policy driving the real JAX engine.

Covers the two ISSUE-1 acceptance properties: (1) the tile overrides
observed by kernels.dispatch change when the policy's interference level
changes; (2) replaying one Workload through the simulator and the engine
yields ServingMetrics with identical request counts and finite latencies.
"""
import math

import jax
import pytest

from repro.configs import get_reduced_config
from repro.core import cost_model as cm
from repro.core.interference import RunningDemand
from repro.core.qos import compare_metrics
from repro.core.scheduler import ModelWisePolicy, VeltairPolicy
from repro.kernels import dispatch
from repro.models import build_model
from repro.serving import (OnlineRuntime, Workload, build_paper_plans,
                           engine_version_sets, replay_through_simulator)
from repro.serving.engine import DEFAULT_LEVEL_TILES, ServingEngine

HW = cm.CPU_3990X
TENANTS = ["resnet50", "googlenet"]


@pytest.fixture(scope="module")
def plans():
    return build_paper_plans(TENANTS, HW)


@pytest.fixture(scope="module")
def engine_factory():
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make(**kw):
        return ServingEngine(cfg, params, batch_slots=2, max_len=32, **kw)
    return make


@pytest.fixture(autouse=True)
def _clean_overrides():
    yield
    dispatch.clear_tile_overrides()


def test_default_level_table_covers_grid_distinctly():
    assert len(DEFAULT_LEVEL_TILES) == cm.NUM_LEVELS
    assert len({t["matmul"]["bm"] for t in DEFAULT_LEVEL_TILES}) \
        == cm.NUM_LEVELS


def test_set_interference_level_installs_overrides(engine_factory):
    engine = engine_factory()
    o0 = engine.set_interference_level(0.0)
    assert dispatch.tile_overrides("matmul") == o0["matmul"]
    o1 = engine.set_interference_level(1.0)
    assert o1 != o0
    assert dispatch.tile_overrides("matmul") == o1["matmul"]
    assert dispatch.all_tile_overrides()["attention"] == o1["attention"]
    # idempotent: same level does not count as a switch
    before = engine.level_switches
    engine.set_interference_level(1.0)
    assert engine.level_switches == before


def test_version_set_tiles_come_from_compiled_plan(plans, engine_factory):
    engine = engine_factory(version_sets=engine_version_sets(plans))
    o0 = engine.set_interference_level(0.0)
    o1 = engine.set_interference_level(1.0)
    assert o0 != o1, "compiled table must swap versions across the range"
    vs = engine._tile_source
    keys = {(v.bm, v.bk, v.bn) for v in vs.versions}
    assert (o0["matmul"]["bm"], o0["matmul"]["bk"],
            o0["matmul"]["bn"]) in keys
    assert (o1["matmul"]["bm"], o1["matmul"]["bk"],
            o1["matmul"]["bn"]) in keys


def test_policy_level_drives_override_change(plans, engine_factory):
    """The acceptance path: the *policy's* interference level changes ->
    the overrides kernels.dispatch observes change."""
    policy = VeltairPolicy(HW)
    engine = engine_factory()
    now = 1.0
    quiet = policy.online_level([], now)
    heavy_demands = [
        RunningDemand(tenant=i, bw=0.9, cache=1.2, ici=0.0,
                      start=0.0, finish=10.0) for i in range(3)]
    loud = policy.online_level(heavy_demands, now)
    assert loud > quiet

    engine.set_interference_level(quiet)
    seen_quiet = dispatch.tile_overrides("matmul")
    engine.set_interference_level(loud)
    seen_loud = dispatch.tile_overrides("matmul")
    assert seen_quiet != seen_loud
    # baselines pin the solo version: level 0 regardless of pressure
    assert ModelWisePolicy(HW).online_level(heavy_demands, now) == 0.0


def test_sim_and_engine_replay_same_workload(plans, engine_factory):
    from repro.serving.simulator import Simulator

    wl = Workload.poisson(TENANTS, 60, 10, prompt_len=4, max_new_tokens=3,
                          seed=2)
    engine = engine_factory()
    runtime = OnlineRuntime(engine, VeltairPolicy(HW), plans, HW)
    m_eng = runtime.serve(wl)
    sim = Simulator(HW, plans, VeltairPolicy(HW))
    m_sim = sim.run(list(wl.arrivals))

    assert m_eng.n_queries == m_sim.n_queries == wl.n_queries
    for m in (m_eng, m_sim):
        assert math.isfinite(m.avg_latency_s) and m.avg_latency_s > 0
        assert math.isfinite(m.p99_latency_s)

    def by_tenant(records):
        out = {}
        for r in records:
            out[r.tenant] = out.get(r.tenant, 0) + 1
        return out
    assert by_tenant(runtime.records) == by_tenant(sim.records)
    table = compare_metrics(m_sim, m_eng)
    assert set(table) >= {"qos_rate", "avg_latency_s", "n_queries"}
    # the convenience wrapper reproduces the direct Simulator run
    m_sim2 = replay_through_simulator(wl, HW, plans, VeltairPolicy(HW))
    assert m_sim2.n_queries == m_sim.n_queries


def test_runtime_levels_respond_to_load(plans, engine_factory):
    """Under a bursty arrival stream the policy must actually move the
    level (the engine sees >1 distinct code version)."""
    wl = Workload.poisson(TENANTS, 200, 10, prompt_len=4, max_new_tokens=3,
                          seed=3)
    engine = engine_factory()
    runtime = OnlineRuntime(engine, VeltairPolicy(HW), plans, HW)
    runtime.serve(wl)
    assert len({cm.level_to_idx(l) for l in runtime.level_trace}) > 1
    assert engine.level_switches >= 1
