"""Paged KV cache: release-path invalidation (the regression the dense
path shipped without), paged-vs-dense token identity under staggered
mixed-length admissions (XLA + Pallas interpret), page-pool admission
pressure (deferred admissions are counted, never silent), cross-request
prefix sharing with copy-on-write, and the free-page headroom clamp the
SLO scheduler consults before sizing a decode quantum."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.qos import DEFAULT_TIERS
from repro.kernels import dispatch
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.paging import TRASH_PAGE, PagePool
from repro.serving.slo import AdmissionController, SloEntry, pick_quantum

MAX_LEN = 32
PAGE = 8
N_NEW = 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(autouse=True)
def _clean_dispatch():
    yield
    dispatch.set_mode("xla")
    dispatch.clear_tile_overrides()


def _mixed_requests(cfg, n_new=N_NEW):
    """Mixed-length prompts; even-indexed ones share a 10-token prefix."""
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    reqs = []
    for i, extra in enumerate((3, 5, 7, 2, 9)):
        tail = rng.integers(0, cfg.vocab_size, extra).astype(np.int32)
        p = (np.concatenate([shared, tail]) if i % 2 == 0 else
             rng.integers(0, cfg.vocab_size, 8 + extra).astype(np.int32))
        reqs.append(Request(rid=i, prompt=p, max_new_tokens=n_new))
    return reqs


def _staggered(cfg, params, paged, slots=3, **kw):
    """Admit at different steps so slot reuse and mid-flight joins happen."""
    eng = ServingEngine(cfg, params, batch_slots=slots, max_len=MAX_LEN,
                        page_size=PAGE if paged else None, **kw)
    reqs = _mixed_requests(cfg)
    assert eng.admit_request(reqs[0], drain=True)
    eng.step()                            # slot 0 is a token ahead
    for r in reqs[1:slots]:
        assert eng.admit_request(r, drain=True)
    eng.run_to_completion(reqs[slots:])
    assert all(r.done for r in reqs)
    return {r.rid: list(r.output) for r in reqs}, eng


# ---------------------------------------------------------------------------
# Release-path invalidation — the regression test comes first: a freed
# slot's cache state must be scrubbed AT RELEASE, not merely papered over
# by the next admission's pristine-row prefill.


def test_release_invalidates_freed_rows_dense(setup):
    """Dense regression: after a request completes, every cache leaf must
    be zero again — the previous tenant's KV is unreachable by
    construction, not by hoping the next prefill overwrites it."""
    cfg, _, params = setup
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN)
    rng = np.random.default_rng(3)
    p = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    done = eng.run_to_completion([Request(rid=0, prompt=p,
                                          max_new_tokens=N_NEW)])
    assert done and done[0].done
    for path, leaf in jax.tree_util.tree_leaves_with_path(eng.cache):
        assert not np.any(np.asarray(leaf)), \
            f"released slot leaked state through cache leaf {path}"


def test_release_drops_page_references_paged(setup):
    """Paged counterpart: release is a refcount decrement — after all
    requests finish the pool must fully drain (no leaked pages, no
    dangling commitment) and the slot's table row parks on the trash
    page."""
    cfg, _, params = setup
    _, eng = _staggered(cfg, params, paged=True)
    assert eng.pool.used_pages == 0, eng.page_stats
    assert eng.pool.committed == 0, eng.page_stats
    assert np.all(eng._page_table == TRASH_PAGE)
    eng._sync_table()                     # device table syncs lazily
    assert np.all(np.asarray(eng.cache["page_table"]) == TRASH_PAGE)


# ---------------------------------------------------------------------------
# Token identity: the paged gather/scatter decode and prefill paths must
# reproduce the dense engine bit-for-bit.


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_paged_matches_dense_staggered(setup, mode):
    cfg, _, params = setup
    dispatch.set_mode(mode)
    want, de = _staggered(cfg, params, paged=False)
    got, pe = _staggered(cfg, params, paged=True)
    assert got == want, (mode, got, want)
    assert pe.peak_cache_tokens > 0
    assert pe.cache_utilization > 0
    # paged residency never exceeds the dense footprint at equal slots
    assert pe.pool.peak_used * PAGE <= de.slots * de.max_len


def test_prompt_of_exactly_max_len_minus_one(setup):
    """Boundary: a max_len-1 prompt decodes exactly one token (the last
    cache position) then finishes on the length clamp — identically on
    both paths, with the paged run touching its final page."""
    cfg, _, params = setup
    rng = np.random.default_rng(5)
    p = rng.integers(0, cfg.vocab_size, MAX_LEN - 1).astype(np.int32)
    outs = {}
    for paged in (False, True):
        eng = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN,
                            page_size=PAGE if paged else None)
        req = Request(rid=0, prompt=p, max_new_tokens=N_NEW)
        eng.run_to_completion([req])
        assert req.done
        outs[paged] = list(req.output)
        if paged:
            assert eng.pool.used_pages == 0, eng.page_stats
    assert outs[True] == outs[False]
    assert len(outs[True]) == 2           # prefill token + one decode step


def test_slot_reuse_after_page_pool_deferral(setup):
    """A request refused on page-pool exhaustion (counted as a conflict)
    must admit cleanly once the resident request frees its pages — and
    the reused pages must not leak the previous tenant's KV."""
    cfg, _, params = setup
    rng = np.random.default_rng(13)
    a = rng.integers(0, cfg.vocab_size, 17).astype(np.int32)
    b = rng.integers(0, cfg.vocab_size, 17).astype(np.int32)

    def solo(p):
        eng = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN)
        req = Request(rid=0, prompt=p, max_new_tokens=N_NEW)
        eng.run_to_completion([req])
        return list(req.output)

    want_a, want_b = solo(a), solo(b)
    # pool sized so A's worst-case commitment starves B despite slot 1
    # being free: ceil((17+4)/8)=3 pages committed of 4 total
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                        page_size=PAGE, n_pages=4)
    ra = Request(rid=0, prompt=a, max_new_tokens=N_NEW)
    rb = Request(rid=1, prompt=b, max_new_tokens=N_NEW)
    assert eng.admit_request(ra, drain=True)
    needed, free = eng.admission_pages(b, N_NEW)
    assert free is not None and needed > free
    assert not eng.admit_request(rb, drain=True)   # deferred, counted
    assert eng.page_stats["conflicts"] >= 1
    eng.run_to_completion([rb])                    # admits after A frees
    assert ra.done and rb.done
    assert list(ra.output) == want_a
    assert list(rb.output) == want_b
    assert eng.pool.used_pages == 0 and eng.pool.committed == 0


def test_prefix_sharing_and_copy_on_write(setup):
    """Staggered arrivals against a resident request: a full-page prefix
    share, a partial-tail borrow, and the borrower's first decode write
    privatizing the shared page — all token-identical to dense."""
    cfg, _, params = setup

    def run(paged):
        rng = np.random.default_rng(11)
        base = rng.integers(0, cfg.vocab_size, 17).astype(np.int32)
        r0 = Request(rid=0, prompt=base, max_new_tokens=6)
        r1 = Request(rid=1, prompt=np.concatenate(
            [base[:10],
             rng.integers(0, cfg.vocab_size, 4).astype(np.int32)]),
            max_new_tokens=6)
        r2 = Request(rid=2, prompt=base[:12].copy(), max_new_tokens=6)
        eng = ServingEngine(cfg, params, batch_slots=3, max_len=MAX_LEN,
                            page_size=PAGE if paged else None)
        assert eng.admit_request(r0, drain=True)
        eng.step_quantum(2)               # r0 publishes its prompt pages
        assert eng.admit_request(r1, drain=True)
        assert eng.admit_request(r2, drain=True)
        eng.run_to_completion([])
        return {r.rid: list(r.output) for r in (r0, r1, r2)}, eng

    want, _ = run(False)
    got, pe = run(True)
    assert got == want, (got, want)
    st = pe.page_stats
    assert st["shared_hits"] >= 2, st     # r1 full page + r2 partial tail
    assert st["cow_copies"] >= 1, st      # r2's decode privatized its page
    assert pe.pool.used_pages == 0 and pe.pool.committed == 0


# ---------------------------------------------------------------------------
# Memory as a scheduling dimension.


def test_decode_k_headroom_clamps_quantum(setup):
    """With one free page a 16-step quantum would cross two page
    boundaries; the engine must clamp to the 8 steps the pool can map."""
    cfg, _, params = setup
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN,
                        page_size=PAGE, n_pages=2, page_reserve="prompt")
    rng = np.random.default_rng(17)
    p = rng.integers(0, cfg.vocab_size, PAGE).astype(np.int32)
    assert eng.admit_request(Request(rid=0, prompt=p, max_new_tokens=16),
                             drain=True)
    assert eng.pool.free_pages == 1
    assert eng.decode_k_headroom(16) == 8
    assert eng.decode_k_headroom(4) == 4          # within one page
    dense = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN)
    assert dense.decode_k_headroom(16) == 16      # dense: passthrough


def test_pick_quantum_consults_page_headroom():
    """The EDF scheduler clamps decode quanta through the engine's
    headroom hook when present, and passes k through otherwise."""

    class _Book:
        def get(self, rid):
            return None

    class _Paged:
        def prefill_queue(self):
            return []

        def decode_backlog(self):
            return [(0, 0, 5)]

        def decode_k_headroom(self, k):
            return min(k, 3)

    class _Dense(_Paged):
        decode_k_headroom = None          # not callable -> no clamp

    assert pick_quantum(_Paged(), _Book(), 0.0, 1e-3, 16) == ("decode", 3)
    assert pick_quantum(_Dense(), _Book(), 0.0, 1e-3, 16) == ("decode", 16)


def test_admission_controller_defers_on_page_shortage():
    """Page-pool exhaustion is an admission dimension: a worst-case
    commitment larger than the uncommitted surplus defers even with a
    slot free; dense engines (pages_free=None) skip the gate."""
    ac = AdmissionController()
    spec = DEFAULT_TIERS["standard"]
    entry = SloEntry(rid=0, tenant="t", tier="standard", arrival=0.0,
                     qos_s=1.0, deadline=2.5, ttft_deadline=1.5)
    kw = dict(now=0.0, entry=entry, spec=spec, step_dt=1e-3, own_chunks=1,
              own_decode_steps=4, backlog_chunks=0, slot_free=True)
    assert ac.decide(**kw, pages_needed=3, pages_free=2) == "defer"
    assert ac.decide(**kw, pages_needed=2, pages_free=2) == "admit"
    assert ac.decide(**kw, pages_needed=3, pages_free=None) == "admit"


def test_page_pool_refcounts_and_commitment():
    """PagePool invariants without a device: reserved allocations can
    never fail, unreserved allocations respect outstanding commitment,
    publish/lookup share refcounted pages, release drains."""
    pool = PagePool(4, 8)
    assert pool.commit(3)
    assert not pool.commit(2)             # over-commit refused, counted
    assert pool.conflicts == 1
    owned = [pool.alloc(reserved=True) for _ in range(3)]
    assert all(p is not None and p != TRASH_PAGE for p in owned)
    assert pool.committed == 0
    assert pool.alloc(reserved=False) is not None   # last truly-free page
    assert pool.alloc(reserved=False) is None       # empty -> stall counted
    assert pool.stalls == 1
    chain, toks = (), tuple(range(8))
    pool.publish(chain, toks, owned[0])
    assert pool.lookup(chain, toks) == owned[0]
    assert pool.lookup_covering(chain, toks[:5]) == owned[0]
    pool.retain(owned[0])                 # a second request maps the page
    assert pool.refcount(owned[0]) == 2
    pool.release(owned[0])
    assert pool.lookup(chain, toks) == owned[0]     # survives: holder left
    pool.release(owned[0])
    assert pool.lookup(chain, toks) is None         # refcount 0 unpublishes
    assert pool.free_pages == 1
