"""Measured counters + online proxy re-fit (ISSUE-8 tentpole, part 1).

(1) Oracle regression: ``read_counters(source="oracle")`` is byte-for-
byte the pre-measurement synthesizer — same rng, same values — and the
offline calibration numbers from PR 3 still hold exactly.
(2) CounterBank semantics: cold-bank fallback, floor/median slowdown,
the slowdown -> level -> Interference -> counter-units round trip of
``sample()``, and wall-jitter robustness (median, not mean).
(3) Attribution contract on the real engine: ``t0`` is stamped after
the version-cache lookup (host compile time never reads as slowdown),
a jax trace inside the timed span drops the observation, and only the
finishing prefill chunk observes.
(4) End-to-end: a single-tenant measured serve agrees with the oracle
level (bounded, wall-noise-tolerant), and ServingMetrics carries the
proxy accounting (``proxy_rms_error`` / ``refit_count``).
(5) RLS drift property (hypothesis): a consistent stream never
triggers a refit; a drifted counter->pressure mapping triggers >= 1
window refit and the proxy converges onto the new regime.
"""
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core.counters import (MIN_KEY_OBS, SLOWDOWN_AT_1, CounterBank,
                                 QuantumObservation)
from repro.core.interference import (DRIFT_SPIKE, DRIFT_WINDOW,
                                     CounterSample, LinearProxy,
                                     RunningDemand, calibrate_proxy,
                                     read_counters, synthesize_counters)
from repro.core.scheduler import FixedBlockPolicy, VeltairPolicy
from repro.serving import OnlineRuntime, Workload, build_paper_plans

HW = cm.CPU_3990X
Itf = cm.Interference


# ---------------------------------------------------------------------------
# (1) oracle regression — source="oracle" is exactly the legacy sensor
# ---------------------------------------------------------------------------
def test_oracle_source_is_byte_identical_to_legacy_default():
    demands = [RunningDemand(tenant=0, bw=0.6, cache=0.8, ici=0.0,
                             start=0.0, finish=10.0)]
    a = read_counters(HW, -1, demands, 1.0, np.random.default_rng(42))
    b = read_counters(HW, -1, demands, 1.0, np.random.default_rng(42),
                      source="oracle")
    assert np.array_equal(a.values, b.values)
    assert a.truth == b.truth
    assert a.source == b.source == "oracle"


def test_oracle_calibration_regression():
    # PR 3's calibration quality bar must survive the sensor refactor:
    # same seed, same rng draw order, same fit
    proxy, counters, levels = calibrate_proxy(HW, n=512, seed=0)
    assert proxy.r2 > 0.9, proxy.r2
    preds = np.array([proxy.predict(c) for c in counters])
    assert np.abs(preds - levels).mean() < 0.08
    assert np.isfinite(proxy.base_rms) and proxy.base_rms < 0.08
    assert proxy.refit_count == 0 and proxy.rls_updates == 0


def test_read_counters_rejects_unknown_source_and_missing_bank():
    with pytest.raises(ValueError, match="counter source"):
        read_counters(HW, -1, [], 0.0, np.random.default_rng(0),
                      source="psychic")
    with pytest.raises(ValueError, match="CounterBank"):
        read_counters(HW, -1, [], 0.0, np.random.default_rng(0),
                      source="measured", bank=None)


# ---------------------------------------------------------------------------
# (2) CounterBank semantics
# ---------------------------------------------------------------------------
def test_cold_bank_falls_back_to_oracle():
    bank = CounterBank()
    s = read_counters(HW, -1, [], 0.0, np.random.default_rng(0),
                      source="measured", bank=bank)
    assert s.source == "oracle"          # fallback is labelled, not hidden
    assert s.truth is not None
    # one observation is below MIN_KEY_OBS: still cold
    bank.observe("decode", 8, (("matmul", (64, 64, 64)),), 1e-3)
    assert bank.slowdown() is None and bank.sample(HW, 0.0) is None


def test_slowdown_is_median_over_floor():
    bank = CounterBank()
    key = ("decode", 8, (("matmul", (64, 64, 64)),))
    walls = [1.0e-3, 1.0e-3, 1.5e-3, 1.5e-3, 2.0e-3]
    for w in walls:
        bank.observe(*key, w)
    assert bank.observations == len(walls)
    assert bank.last is not None and bank.last.wall_s == walls[-1]
    # floor = 1ms; ratios = [1, 1, 1.5, 1.5, 2] -> median 1.5
    assert bank.slowdown() == pytest.approx(1.5)
    lvl = bank.level()
    assert lvl == pytest.approx(0.5 / SLOWDOWN_AT_1)
    # one outlier spike must not swing the median (robustness knob)
    bank.observe(*key, 50e-3)
    assert bank.slowdown() == pytest.approx(1.5)


def test_bank_ignores_nonpositive_walls_and_uncontended_floor_is_level0():
    bank = CounterBank()
    key = ("decode", 1, (("matmul", (32, 32, 32)),))
    bank.observe(*key, 0.0)
    bank.observe(*key, -1.0)
    assert bank.observations == 0
    for _ in range(MIN_KEY_OBS + 2):
        bank.observe(*key, 2e-3)         # perfectly repeatable walls
    assert bank.slowdown() == pytest.approx(1.0)
    assert bank.level() == pytest.approx(0.0)
    assert bank.pressure() == Itf.from_level(0.0)


def test_bank_sample_is_noise_free_counter_curve():
    """sample() re-expresses measured pressure via the deterministic
    response curve — the transport format the calibrated proxy reads."""
    bank = CounterBank()
    key = ("decode", 8, (("matmul", (64, 64, 64)),))
    bank.observe(*key, 1.0e-3)
    bank.observe(*key, 1.0e-3 * (1.0 + 0.4 * SLOWDOWN_AT_1))
    s = bank.sample(HW, now=3.25)
    assert isinstance(s, CounterSample)
    assert s.source == "measured" and s.truth is None and s.t == 3.25
    itf = bank.pressure()
    expect = synthesize_counters(HW, itf, None, noise_scale=0.0)
    assert np.array_equal(s.values, expect)
    # the calibrated proxy must decode the measured sample back to
    # (approximately) the bank's own level — sensor and decision path
    # speak the same units
    proxy, _, _ = calibrate_proxy(HW)
    assert abs(proxy.predict(s.values) - bank.level()) < 0.08


def test_observation_key_groups_by_kind_bucket_tiles():
    o = QuantumObservation(kind="decode", bucket=8,
                           tiles=(("matmul", (64, 64, 64)),), wall_s=1e-3)
    assert o.key == ("decode", 8, (("matmul", (64, 64, 64)),))
    bank = CounterBank()
    # different tile configs never share a floor: a slow config's wall
    # must not read as interference on the fast config
    bank.observe("decode", 8, ("a",), 1e-3)
    bank.observe("decode", 8, ("a",), 1e-3)
    bank.observe("decode", 8, ("b",), 4e-3)
    bank.observe("decode", 8, ("b",), 4e-3)
    assert bank.slowdown() == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# (3) attribution contract on the real engine
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine_factory():
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model

    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make(**kw):
        from repro.serving.engine import ServingEngine
        kw.setdefault("batch_slots", 2)
        kw.setdefault("max_len", 32)
        return ServingEngine(cfg, params, **kw)
    return make


def _admit(eng, rid, prompt_len=4, max_new_tokens=6):
    from repro.serving.engine import Request
    req = Request(rid=rid, prompt=list(range(1, prompt_len + 1)),
                  max_new_tokens=max_new_tokens)
    assert eng.admit_request(req) is not None
    while eng.prefill_pending:
        eng.prefill_step()
    return req


def test_trace_guard_drops_first_visit_compile(engine_factory):
    """A quantum whose timed span contains a jax trace (cold K-bucket
    compile) must NOT observe — compile time is host cost the runtimes
    charge separately, never interference slowdown."""
    eng = engine_factory()
    _admit(eng, rid=0, max_new_tokens=20)
    obs0 = eng.counter_bank.observations
    # prefill above was the cache's first visit -> traced -> dropped
    assert obs0 == 0
    h = eng.begin_quantum(4, fused=True)     # cold bucket: AOT before t0
    eng.finish_quantum(h)
    n1 = eng.counter_bank.observations
    h = eng.begin_quantum(4, fused=True)     # warm: same bucket, no trace
    eng.finish_quantum(h)
    assert eng.counter_bank.observations == n1 + 1
    last = eng.counter_bank.last
    assert last.kind == "decode" and last.bucket == 4
    assert last.wall_s > 0.0


def test_t0_excludes_host_side_delay(engine_factory, monkeypatch):
    """Host-side work before dispatch (scheduler deliberation, a slow
    version-cache lookup) must not inflate the observed wall: t0 is
    stamped after the executable lookup, immediately before dispatch."""
    import time as _time

    eng = engine_factory()
    eng.warmup()
    _admit(eng, rid=0, max_new_tokens=20)
    # settle the floor on warm quanta first
    for _ in range(3):
        eng.finish_quantum(eng.begin_quantum(4, fused=True))
    floor = min(o.wall_s for o in eng.counter_bank._recent)

    real_quantum = eng.version_cache.quantum
    delay = 0.05

    def slow_lookup(*a, **kw):               # 50ms of pure host-side stall
        _time.sleep(delay)
        return real_quantum(*a, **kw)

    monkeypatch.setattr(eng.version_cache, "quantum", slow_lookup)
    h = eng.begin_quantum(4, fused=True)
    eng.finish_quantum(h)
    last = eng.counter_bank.last
    # the 50ms stall happened before t0 — the observation must look like
    # an ordinary warm quantum, nowhere near floor + delay
    assert last.wall_s < floor + delay / 2, (last.wall_s, floor)


def test_prefill_observes_only_finishing_chunk(engine_factory):
    eng = engine_factory()
    eng.warmup()
    obs0 = eng.counter_bank.observations
    from repro.serving.engine import Request
    req = Request(rid=7, prompt=list(range(1, 25)), max_new_tokens=2)
    eng.admit_request(req)
    chunks = 0
    while eng.prefill_pending:
        eng.prefill_step()
        chunks += 1
    assert chunks > 1, "prompt must span multiple chunks for this test"
    # exactly ONE observation — the finishing chunk (the only synced one)
    assert eng.counter_bank.observations == obs0 + 1
    last = eng.counter_bank.last
    assert last.kind == "prefill"
    assert last.bucket == 32             # _next_pow2(24): full-prompt bucket


# ---------------------------------------------------------------------------
# (4) end-to-end: measured serve + metrics accounting
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def plans():
    return build_paper_plans(["resnet50"], HW)


def _runtime(engine_factory, plans, **kw):
    policy = kw.pop("policy", None) or VeltairPolicy(
        HW, proxy=calibrate_proxy(HW)[0])
    eng = engine_factory()
    eng.warmup()
    return OnlineRuntime(eng, policy, plans, HW, seed=3, **kw)


def test_single_tenant_measured_agrees_with_oracle(engine_factory, plans):
    """Single tenant, no co-runners: the oracle says level ~0; the
    measured bank must agree within wall-noise tolerance."""
    wl = Workload.poisson(["resnet50"], 200.0, 24, prompt_len=6,
                          max_new_tokens=4, seed=5)
    rt = _runtime(engine_factory, plans, counter_source="measured")
    m = rt.serve(wl)
    assert m.n_queries == 24
    assert rt.counter_sources["measured"] > 0, "bank never warmed up"
    # alone on the machine the true level is 0.0; host-side wall jitter
    # may read as a small slowdown but never as real contention
    lvl = rt.engine.counter_bank.level()
    assert lvl is not None and lvl < 0.35, lvl
    assert np.mean(rt.level_trace) < 0.35


def test_measured_serve_reports_proxy_accounting(engine_factory, plans):
    wl = Workload.poisson(["resnet50"], 200.0, 16, prompt_len=6,
                          max_new_tokens=4, seed=6)
    rt = _runtime(engine_factory, plans, counter_source="measured")
    assert rt.refit_proxy is True        # measured => online re-fit on
    m = rt.serve(wl)
    assert rt.policy.proxy.rls_updates > 0
    assert np.isfinite(m.proxy_rms_error)
    assert m.proxy_rms_error == pytest.approx(rt.policy.proxy_rms_error)
    assert m.refit_count == rt.policy.proxy.refit_count


def test_oracle_serve_keeps_proxy_frozen(engine_factory, plans):
    """Default (oracle) serving is the PR-3 behavior: no RLS updates, no
    refits, nan rms — the metrics fields exist but stay inert."""
    wl = Workload.poisson(["resnet50"], 200.0, 12, prompt_len=6,
                          max_new_tokens=4, seed=7)
    rt = _runtime(engine_factory, plans)          # counter_source="oracle"
    assert rt.refit_proxy is False
    m = rt.serve(wl)
    assert rt.counter_sources == {"oracle": rt.counter_sources["oracle"]}
    assert rt.policy.proxy.rls_updates == 0
    assert m.refit_count == 0
    assert not np.isfinite(m.proxy_rms_error)


def test_fixed_policy_reports_inert_proxy_fields(engine_factory, plans):
    wl = Workload.poisson(["resnet50"], 200.0, 8, prompt_len=4,
                          max_new_tokens=2, seed=8)
    rt = _runtime(engine_factory, plans,
                  policy=FixedBlockPolicy(HW, block_size=6),
                  counter_source="measured")
    m = rt.serve(wl)                     # observe_counters is a no-op here
    assert m.refit_count == 0
    assert not np.isfinite(m.proxy_rms_error)


# ---------------------------------------------------------------------------
# (5) RLS drift property
# ---------------------------------------------------------------------------
def _pairs(rng, n, miss_gain):
    """(counters, pressure) pairs from a counter->pressure mapping with a
    configurable miss-rate gain (0.85 is the calibration-time truth)."""
    out = []
    for _ in range(n):
        itf = Itf.from_level(rng.uniform())
        c = min(itf.cache / Itf.CACHE_AT_1, 1.0)
        b = min(itf.bw / Itf.BW_AT_1, 1.0)
        vals = np.array([0.08 + miss_gain * c + rng.normal(0, 0.015),
                         0.20 + 0.75 * b + rng.normal(0, 0.02)])
        out.append((vals, itf))
    return out


def test_consistent_stream_never_refits():
    proxy, _, _ = calibrate_proxy(HW)
    rng = np.random.default_rng(1)
    for vals, itf in _pairs(rng, 3 * DRIFT_WINDOW, miss_gain=0.85):
        proxy.rls_update(vals, itf)
    assert proxy.refit_count == 0
    assert proxy.rms_error < DRIFT_SPIKE * proxy.base_rms


def test_drift_triggers_refit_and_converges():
    proxy, _, _ = calibrate_proxy(HW)
    base = proxy.base_rms
    rng = np.random.default_rng(2)
    for vals, itf in _pairs(rng, 60, miss_gain=0.85):
        proxy.rls_update(vals, itf)
    assert proxy.refit_count == 0
    # regime change: the miss-rate response flattens (0.85 -> 0.4)
    drifted = _pairs(rng, 80, miss_gain=0.4)
    for vals, itf in drifted:
        proxy.rls_update(vals, itf)
    assert proxy.refit_count >= 1, "drift detector never fired"
    # converged onto the NEW mapping: held-out drifted pairs predict well
    errs = [np.linalg.norm(proxy._target(itf) -
                           (proxy.w @ vals + proxy.b))
            for vals, itf in _pairs(rng, 64, miss_gain=0.4)]
    assert float(np.sqrt(np.mean(np.square(errs)))) < \
        DRIFT_SPIKE * max(base, 1e-3)
    assert proxy.rms_error < DRIFT_SPIKE * proxy.base_rms


def test_drift_property_random_gains():
    hypothesis = pytest.importorskip("hypothesis")
    given, st = hypothesis.given, pytest.importorskip(
        "hypothesis.strategies")

    @given(gain=st.floats(min_value=0.0, max_value=0.45),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    @hypothesis.settings(max_examples=15, deadline=None)
    def prop(gain, seed):
        proxy, _, _ = calibrate_proxy(HW)
        rng = np.random.default_rng(seed)
        for vals, itf in _pairs(rng, 40, miss_gain=0.85):
            proxy.rls_update(vals, itf)
        pre = proxy.refit_count
        for vals, itf in _pairs(rng, 6 * DRIFT_WINDOW, miss_gain=gain):
            proxy.rls_update(vals, itf)
        # any sufficiently large gain collapse must fire the detector...
        assert proxy.refit_count >= pre + 1
        # ...and the refit resets the drift floor so it fires O(1) times,
        # not once per post-drift sample
        assert proxy.refit_count <= pre + 4

    prop()


def test_refit_resets_residual_window():
    proxy = LinearProxy()
    proxy.w = np.zeros((2, 2))
    proxy.b = np.zeros(2)
    proxy.base_rms = 1e-3
    rng = np.random.default_rng(3)
    for vals, itf in _pairs(rng, 2 * DRIFT_WINDOW, miss_gain=0.85):
        proxy.rls_update(vals, itf)
    assert proxy.refit_count >= 1        # zero model = instant drift
    # post-refit the residual window holds at most DRIFT_WINDOW entries
    # (the new normal), and base_rms moved off the tiny seed value
    assert len(proxy._residuals) <= proxy._win.maxlen
    assert proxy.base_rms > 1e-3 or proxy.base_rms == 1e-3
    assert np.isfinite(proxy.rms_error)
