"""Sharding rules, pspec derivation, HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.launch import hlo_stats
from repro.models.params import ParamSpec


@pytest.fixture(scope="module")
def mesh():
    # single-device mesh with named axes of size 1 keeps tests runnable
    return jax.make_mesh((1, 1), ("data", "model"))


def _mesh_16_16():
    """Fake mesh-shape lookup for divisibility tests (no devices needed)."""
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    return FakeMesh()


def test_pspec_divisibility_drop():
    mesh = _mesh_16_16()
    rules = shd.make_rules("train").params
    # kv_heads=8 cannot shard over model=16 -> dropped (GQA TP fallback)
    spec = shd.pspec_for((1024, 8, 128), ("embed", "kv_heads", "head_dim"),
                         rules, mesh)
    assert spec == P("data")
    # heads=128 shards fine
    spec2 = shd.pspec_for((1024, 128, 128), ("embed", "heads", "head_dim"),
                          rules, mesh)
    assert spec2 == P(("data",), "model")


def test_pspec_no_duplicate_mesh_axes():
    mesh = _mesh_16_16()
    rules = {"a": "model", "b": "model"}
    spec = shd.pspec_for((64, 64), ("a", "b"), rules, mesh)
    # 'model' used once only
    used = [e for e in spec if e is not None]
    assert used in ([("model",)], ["model"]) or len(used) == 1


def test_multi_axis_product_sharding():
    mesh = type("M", (), {"shape": {"pod": 2, "data": 16, "model": 16}})()
    rules = {"batch": ("pod", "data")}
    spec = shd.pspec_for((256, 128), ("batch", None), rules, mesh)
    assert spec == P(("pod", "data"))
    # non-divisible by the product: drops trailing axis
    spec2 = shd.pspec_for((2, 128), ("batch", None), rules, mesh)
    assert spec2 == P(("pod",))


def test_hint_noop_outside_context():
    x = jnp.ones((4, 4))
    assert shd.hint(x, ("batch", None)) is x


def test_hint_constrains_inside_context(mesh):
    rules = shd.make_rules("train")

    @jax.jit
    def f(x):
        with shd.use_rules(mesh, rules):
            return shd.hint(x, ("batch", "embed")) * 2
    out = f(jnp.ones((4, 8)))
    assert out.shape == (4, 8)


def test_device_bytes():
    mesh = _mesh_16_16()
    specs = {"w": ParamSpec((1024, 256), jnp.bfloat16, ("embed", "mlp"))}
    rules = shd.make_rules("train")
    pspecs = shd.param_pspecs(specs, rules, mesh)
    total = shd.device_bytes(pspecs, specs, mesh)
    assert total == 1024 * 256 * 2 // (16 * 16)


# --------------------------------------------------------------------------
# HLO collective parsing
# --------------------------------------------------------------------------
HLO_SAMPLE = """
HloModule test
  %ar = bf16[16,1024]{1,0} all-reduce(bf16[16,1024] %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[64,512]{1,0} all-gather(bf16[16,512] %y), replica_groups=[8,4]<=[32], dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(f32[32,128] %z), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = bf16[4,4]{1,0} collective-permute(bf16[4,4] %w), source_target_pairs={{0,1}}
  %dot = bf16[4,4]{1,0} dot(bf16[4,4] %a, bf16[4,4] %b)
"""


def test_parse_collectives_counts_and_bytes():
    st = hlo_stats.parse_collectives(HLO_SAMPLE)
    assert st.counts["all-reduce"] == 1
    assert st.counts["all-gather"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["collective-permute"] == 1
    assert st.counts["all-to-all"] == 0
    ar_bytes = 16 * 1024 * 2
    ag_bytes = 64 * 512 * 2
    rs_bytes = 8 * 128 * 4
    assert st.payload_bytes["all-reduce"] == ar_bytes
    assert st.payload_bytes["all-gather"] == ag_bytes
    expected_link = (2 * 3 / 4 * ar_bytes + 3 / 4 * ag_bytes
                     + 3 * rs_bytes + 4 * 4 * 2)
    assert np.isclose(st.link_bytes, expected_link, rtol=1e-6)


def test_parse_collectives_start_variant_halved():
    text = ("%ags = (bf16[8,8]{1,0}, bf16[32,8]{1,0}) "
            "all-gather-start(bf16[8,8] %p), replica_groups=[1,4]<=[4], "
            "dimensions={0}\n")
    st = hlo_stats.parse_collectives(text)
    assert st.counts["all-gather"] == 1
    # tuple bytes halved: (64+256)*2/2 = 320
    assert st.payload_bytes["all-gather"] == (8 * 8 + 32 * 8) * 2 // 2
