"""Training substrate: optimizers, schedules, accumulation, loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model, make_sample_inputs
from repro.training import OptimizerConfig, TrainConfig, schedule_fn
from repro.training.train_step import (init_train_state, make_train_step,
                                       params_of)

SMOKE = ShapeConfig("smoke", seq_len=16, global_batch=4, mode="train")


def test_wsd_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                          total_steps=100, stable_fraction=0.8,
                          min_lr_ratio=0.1)
    f = schedule_fn(cfg)
    assert 0.0 < float(f(0)) <= 0.2      # first-step warmup fraction
    assert np.isclose(float(f(10)), 1.0)
    assert np.isclose(float(f(50)), 1.0)          # stable plateau
    assert float(f(90)) < 1.0                      # decaying
    assert np.isclose(float(f(100)), 0.1)          # floor


def test_cosine_schedule_monotone_decay():
    cfg = OptimizerConfig(lr=1.0, schedule="cosine", warmup_steps=5,
                          total_steps=50)
    f = schedule_fn(cfg)
    vals = [float(f(s)) for s in range(5, 51, 5)]
    assert all(b <= a + 1e-6 for a, b in zip(vals, vals[1:]))


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_loss_decreases(opt):
    cfg = get_reduced_config("starcoder2-3b")
    model = build_model(cfg)
    tc = TrainConfig(optimizer=OptimizerConfig(
        name=opt, lr=3e-3, warmup_steps=2, total_steps=12))
    state = init_train_state(model, jax.random.PRNGKey(0), tc)
    step = jax.jit(make_train_step(model, tc))
    batch = make_sample_inputs(cfg, SMOKE)
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_grad_accumulation_matches_single_step():
    """accum=2 over a batch == accum=1 over the same batch (same grads)."""
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    batch = make_sample_inputs(cfg, SMOKE)

    outs = {}
    for accum in (1, 2):
        tc = TrainConfig(optimizer=OptimizerConfig(lr=1e-2, warmup_steps=0,
                                                   total_steps=4),
                         accum_steps=accum)
        state = init_train_state(model, jax.random.PRNGKey(0), tc)
        step = jax.jit(make_train_step(model, tc))
        state, m = step(state, batch)
        outs[accum] = (params_of(state, model), float(m["loss"]))
    p1, l1 = outs[1]
    p2, l2 = outs[2]
    # losses are means over the same tokens; params must agree closely
    assert np.isclose(l1, l2, rtol=2e-2)
    leaves1 = jax.tree_util.tree_leaves(p1)
    leaves2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(leaves1, leaves2):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        # bf16 forward noise through Adam's rsqrt flips a handful of tiny
        # elements at step 0 — require aggregate agreement, allow a small
        # tail of element-wise outliers.
        assert np.mean(np.abs(a - b)) < 2e-3
        frac_bad = np.mean(~np.isclose(a, b, rtol=5e-2, atol=5e-3))
        assert frac_bad < 0.01, f"{frac_bad:.3%} elements mismatched"


def test_data_pipeline_deterministic_and_sharded():
    base = dict(vocab_size=1000, seq_len=8, global_batch=8, seed=3)
    p1 = TokenPipeline(DataConfig(**base))
    p2 = TokenPipeline(DataConfig(**base))
    np.testing.assert_array_equal(p1.batch(5)["tokens"],
                                  p2.batch(5)["tokens"])
    # shards are disjoint slices of the same global batch size
    s0 = TokenPipeline(DataConfig(**base, num_shards=2, shard_index=0))
    s1 = TokenPipeline(DataConfig(**base, num_shards=2, shard_index=1))
    b0, b1 = s0.batch(0)["tokens"], s1.batch(0)["tokens"]
    assert b0.shape == (4, 8) and b1.shape == (4, 8)
    assert not np.array_equal(b0, b1)


def test_grad_clip():
    from repro.training.optimizer import clip_by_global_norm
    tree = {"a": jnp.full((4,), 100.0), "b": jnp.full((3,), -100.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    from repro.training.optimizer import global_norm
    assert float(norm) > 100
    assert np.isclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_int8_grad_compression_with_error_feedback():
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    tc = TrainConfig(optimizer=OptimizerConfig(lr=3e-3, warmup_steps=2,
                                               total_steps=12),
                     grad_compression="int8")
    from repro.training.train_step import init_train_state, make_train_step
    state = init_train_state(model, jax.random.PRNGKey(0), tc)
    assert "ef" in state
    step = jax.jit(make_train_step(model, tc))
    batch = make_sample_inputs(cfg, SMOKE)
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    # compressed grads must still train (error feedback preserves signal)
    assert losses[-1] < losses[0] * 0.85, losses
    # residual state is alive (non-zero quantization error carried)
    ef_norm = sum(float(jnp.sum(jnp.abs(l)))
                  for l in jax.tree_util.tree_leaves(state["ef"]))
    assert ef_norm > 0
