"""Property tests for the single-pass multi-version compiler (Alg. 1)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import cost_model as cm
from repro.core import schedule_space as ss
from repro.core.multiversion import (V_MAX, compile_layer, extract_dominant)


def _layer(m, k, n, it=4):
    return cm.GemmLayer(name=f"g{m}x{k}x{n}", m=m, k=k, n=n, itemsize=it)


@given(m=st.integers(8, 800), k=st.integers(8, 3000), n=st.integers(8, 800))
@settings(max_examples=15, deadline=None)
def test_pareto_frontier_properties(m, k, n):
    hw = cm.CPU_3990X
    vs = ss.enumerate_versions(_layer(m, k, n), hw)
    dom = extract_dominant(vs)
    assert dom, "frontier never empty"
    # 1. no kept version dominated by ANY candidate
    for d in dom:
        for v in vs:
            dominated = (v.parallelism >= d.parallelism
                         and v.locality >= d.locality
                         and (v.parallelism > d.parallelism
                              or v.locality > d.locality))
            assert not dominated, (d, v)
    # 2. frontier is an antichain: sorted by locality => parallelism strictly
    # decreasing
    by_loc = sorted(dom, key=lambda v: v.locality)
    for a, b in zip(by_loc, by_loc[1:]):
        assert b.parallelism < a.parallelism or b.locality > a.locality


@given(m=st.integers(16, 600), k=st.integers(64, 2500),
       n=st.integers(16, 600))
@settings(max_examples=15, deadline=None)
def test_compile_layer_invariants(m, k, n):
    hw = cm.CPU_3990X
    vset = compile_layer(_layer(m, k, n), hw, qos_budget_s=5e-3)
    # <= V versions, all on the frontier, table indexes valid
    assert 1 <= len(vset.versions) <= V_MAX
    assert len(vset.level_table) == cm.NUM_LEVELS
    assert all(0 <= i < len(vset.versions) for i in vset.level_table)
    # retention: kept-set envelope within 1/RETENTION of the full picked set
    grid = cm.level_grid()
    units = max(hw.n_units // 4, 1)
    # solo selection is optimal at level 0 among kept
    lats0 = [cm.latency(hw, v, units, grid[0]) for v in vset.versions]
    assert vset.level_table[0] == int(np.argmin(lats0))


def test_version_sets_sorted_and_monotone_tables():
    hw = cm.CPU_3990X
    from repro.configs.paper_suite import resnet50
    for lay in resnet50()[:8]:
        vset = compile_layer(lay, hw, qos_budget_s=1e-3)
        tiles = [v.tile_bytes for v in vset.versions]
        assert tiles == sorted(tiles)


def test_interference_monotonicity_of_latency():
    hw = cm.CPU_3990X
    lay = _layer(196, 2304, 256)
    vs = ss.enumerate_versions(lay, hw)
    for v in vs[::17]:
        lats = [cm.latency(hw, v, 16, itf) for itf in cm.level_grid()]
        assert all(b >= a - 1e-12 for a, b in zip(lats, lats[1:])), \
            "latency must be non-decreasing in interference level"


def test_units_monotonicity_of_latency():
    hw = cm.CPU_3990X
    lay = _layer(512, 1024, 512)
    v = ss.default_version(lay, hw)
    lats = [cm.latency(hw, v, u, cm.Interference()) for u in (1, 2, 4, 8,
                                                              16, 32, 64)]
    assert all(b <= a + 1e-12 for a, b in zip(lats, lats[1:])), \
        "latency must be non-increasing in units at zero interference"


def test_crossover_exists_for_llc_bound_layer():
    """The paper's Fig. 6 phenomenon: the solo winner must lose to an
    interference-tolerant version at the top pressure level."""
    hw = cm.CPU_3990X
    from repro.configs.paper_suite import bert_large
    lay = bert_large()[0]
    vs = ss.enumerate_versions(lay, hw)
    grid = cm.level_grid()
    units = 16
    best0 = min(vs, key=lambda v: cm.latency(hw, v, units, grid[0]))
    best9 = min(vs, key=lambda v: cm.latency(hw, v, units, grid[-1]))
    l0_at9 = cm.latency(hw, best0, units, grid[-1])
    l9_at9 = cm.latency(hw, best9, units, grid[-1])
    assert l9_at9 < l0_at9, "tolerant version must win at max interference"
    degradation = l0_at9 / cm.latency(hw, best0, units, grid[0])
    assert degradation > 2.0, f"solo winner must degrade (got {degradation:.1f}x)"


def test_units_required_knee_fallback():
    hw = cm.CPU_3990X
    lay = _layer(64, 512, 64)
    v = ss.default_version(lay, hw)
    # infeasible budget: returns a sane knee, not n_units+1
    u = cm.units_required(hw, v, 1e-9, cm.Interference())
    assert 1 <= u <= hw.n_units
