"""Speculative multi-token decode quanta: draft -> batched verify ->
rollback must be token-for-token identical to non-speculative greedy
decode — the correctness bar is exact token identity, not "close".

Covered here, per cache family (attention / MLA / SSM / hybrid
window+RG-LRU) and in both the XLA reference path and Pallas interpret
mode:

* identity under staggered admissions, mixed prompt lengths,
  mid-quantum completions and level switches at quantum boundaries;
* the rollback path specifically (drafts that verify rejects must leave
  the cache exactly where sequential decode would);
* paged engines: the worst-case d+1 write span is preflighted, partial
  acceptance never leaks trash-page state into emitted tokens;
* zero post-warmup retraces: ``warmup()`` pre-builds the spec verify
  executables alongside the K-buckets, so a serving loop with level
  switches never traces;
* ``spec_recurrent=False`` downgrades recurrent-state models to the
  plain fused quantum (still exact, zero spec quanta).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import cost_model as cm
from repro.kernels import dispatch
from repro.serving.engine import Request, ServingEngine
from repro.serving.speculative import NgramDrafter

MAX_LEN = 64
ARCHS = ("gemma-2b", "deepseek-v2-lite-16b", "mamba2-780m",
         "recurrentgemma-2b")


@pytest.fixture(scope="module", params=ARCHS)
def setup(request):
    from repro.models import build_model
    cfg = get_reduced_config(request.param)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (10, 7, 4)]
    return cfg, model, params, prompts


def _mla_only(cfg):
    """MoE-free clone of an MLA config: first_dense_layers == num_layers
    turns every block into ds_dense0 (MLA attention + dense MLP), so the
    MLA cache family is tested without the MoE router's near-tie expert
    selection amplifying ulp-level drift between the chunked verify
    forward and the sequential decode step."""
    import dataclasses
    from repro.models import build_model
    cfg = dataclasses.replace(cfg, name=cfg.name + "-mla-only",
                              first_dense_layers=cfg.num_layers)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(autouse=True)
def _clean_dispatch():
    yield
    dispatch.set_mode("xla")
    dispatch.clear_tile_overrides()


def _serve(cfg, params, prompts, *, speculative, n_new=(40, 36, 20),
           k=4, levels=(), stagger=True, **engine_kw):
    """Drive a schedule with staggered admissions, mixed lengths and
    mid-quantum completions; level switches at quantum boundaries."""
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                        speculative=speculative, **engine_kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts, n_new))]
    pending = list(reqs)
    if not stagger:
        while pending and eng.admit_request(pending[0], drain=True):
            pending.pop(0)
    for i in range(400):
        if all(r.done for r in reqs):
            break
        if stagger and pending and i % 3 == 0:
            if eng.admit_request(pending[0], drain=True):
                pending.pop(0)
        if levels:
            eng.set_interference_level(levels[i % len(levels)])
        eng.step_quantum(k)
    assert all(r.done for r in reqs), "schedule must drain every request"
    return eng, [list(r.output) for r in reqs]


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_spec_identical_to_plain_greedy(setup, mode):
    """The tentpole bar: speculation changes the schedule, never the
    tokens — under staggered admissions, mixed lengths, mid-quantum
    completions and level switches, per-request streams match the
    non-speculative engine exactly."""
    cfg, _, params, prompts = setup
    dispatch.set_mode(mode)
    _, want = _serve(cfg, params, prompts, speculative=False,
                     levels=(0.0, 1.0, 0.3))
    eng, got = _serve(cfg, params, prompts, speculative=True,
                      levels=(0.0, 1.0, 0.3))
    assert got == want
    # the speculative path actually ran (and the rollback path with it:
    # tiny random models never accept every draft of every quantum)
    assert eng.spec_quanta > 0
    assert eng.tokens_drafted > 0


def test_spec_rollback_only_stream_is_exact(setup):
    """All-rejected drafts are the hardest rollback case (emit exactly
    one corrected token, rewind everything else): force it by drafting
    against histories the model never follows."""
    cfg, _, params, prompts = setup
    if cfg.moe is not None:
        # Unigram drafts drive repeated-token plateaus where the MoE
        # router's top-k sits on ~ulp-wide logit ties; the chunked
        # verify forward and the sequential step then pick different
        # experts and the argmax flips.  Not a rollback bug — the MLA
        # rollback machinery is exercised here on a MoE-free clone
        # (every layer MLA + dense MLP), and deepseek proper is held to
        # full identity in test_spec_identical_to_plain_greedy.
        cfg, params = _mla_only(cfg)
    _, want = _serve(cfg, params, prompts, speculative=False)
    eng, got = _serve(cfg, params, prompts, speculative=True,
                      spec_ngram=1, spec_depth=3)
    assert got == want
    assert eng.spec_quanta > 0


def test_spec_paged_preflight_and_identity(setup):
    """Paged engines preflight the worst-case d+1 writes per row and
    clamp emission to the mapped span — a small pool must degrade to
    fallbacks/stalls, never to wrong tokens.  Models with no pageable
    (linear-KV) cache leaf refuse the paged layout outright."""
    cfg, model, params, prompts = setup
    if not model.paged_leaf_paths():
        with pytest.raises(ValueError, match="no pageable"):
            _serve(cfg, params, prompts, speculative=True,
                   page_size=8, n_pages=24)
        return
    _, want = _serve(cfg, params, prompts, speculative=False)
    eng, got = _serve(cfg, params, prompts, speculative=True,
                      page_size=8, n_pages=24)
    assert got == want
    assert eng.spec_quanta > 0


def test_spec_zero_retraces_after_warmup(setup):
    """warmup() pre-builds every reachable (K-bucket, draft-depth) spec
    executable: a level-sweeping speculative serve afterwards performs
    zero traces and zero version-cache misses."""
    cfg, _, params, prompts = setup
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                        speculative=True)
    eng.warmup(prompt_lens=tuple(len(p) for p in prompts))
    if eng._spec_enabled:
        for entry in eng.version_cache._entries.values():
            assert entry.spec, "verify executables prebuilt at warmup"
    vc = eng.version_cache
    traces0, misses0 = vc.traces, vc.misses
    reqs = [Request(rid=i, prompt=p, max_new_tokens=30)
            for i, p in enumerate(prompts[:2])]
    for r in reqs:
        eng.admit_request(r, drain=True)
    i = 0
    while not all(r.done for r in reqs):
        eng.set_interference_level(cm.grid_point(i % cm.NUM_LEVELS))
        eng.step_quantum(4)
        i += 1
        assert i < 400
    assert vc.traces == traces0, "no trace after warmup"
    assert vc.misses == misses0, "every spec dispatch is a cache hit"


def test_spec_recurrent_opt_out_falls_back_to_plain_quanta(setup):
    """spec_recurrent=False: engines whose cache holds non-sequence
    (recurrent-state) leaves serve through the plain fused quantum —
    still exact, zero speculative dispatches."""
    cfg, model, params, prompts = setup
    eng, got = _serve(cfg, params, prompts, speculative=True,
                      spec_recurrent=False)
    _, want = _serve(cfg, params, prompts, speculative=False)
    assert got == want
    if model._has_nonseq_cache_leaves():
        assert not eng._spec_enabled
        assert eng.spec_quanta == 0
    else:
        assert eng._spec_enabled     # pure-attention models keep spec on


def test_spec_counters_and_hit_rate_consistency(setup):
    """The surfaced counters stay internally consistent: accepted <=
    drafted, hit rate is their ratio, every spec-eligible dispatch is
    either a spec quantum or a counted fallback."""
    cfg, _, params, prompts = setup
    eng, _ = _serve(cfg, params, prompts, speculative=True)
    s = eng.spec_stats
    assert 0 <= s["tokens_accepted"] <= s["tokens_drafted"]
    assert s["draft_hit_rate"] == pytest.approx(
        s["tokens_accepted"] / max(s["tokens_drafted"], 1))
    assert s["spec_quanta"] + s["spec_fallbacks"] > 0
    assert eng.expected_accept_per_step() >= 1.0


def test_drafter_prompt_lookup():
    """NgramDrafter finds the latest n-gram recurrence, proposes its
    continuation, right-pads near the end, and returns None when
    nothing recurs."""
    d = NgramDrafter(depth=3, max_ngram=2)
    got = d.draft([1, 2, 9, 9, 1, 2])
    assert got is not None and got.tolist() == [9, 9, 1]
    # latest occurrence wins over earlier ones
    got = d.draft([1, 2, 3, 1, 2, 4, 1, 2])
    assert got.tolist() == [4, 1, 2]
    # hit near the end: pad by repeating the last candidate
    got = d.draft([7, 5, 6, 7, 5])
    assert got.tolist() == [6, 7, 5]
    got = d.draft([3, 8, 3])
    assert got.tolist() == [8, 3, 3]
    assert d.draft([1, 2, 3, 4, 5]) is None
    assert d.draft([4]) is None
