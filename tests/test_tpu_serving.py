"""TPU-pod serving path: LM-arch tenants on the v5e pod hardware spec."""
from repro.core import cost_model as cm
from repro.core.scheduler import ModelWisePolicy, VeltairPolicy
from repro.serving import Simulator, lm_serving_plans, poisson_workload


def test_lm_plans_compile_and_serve():
    plans = lm_serving_plans([("gemma-2b", "decode_32k", 40.0),
                              ("mamba2-780m", "decode_32k", 25.0)])
    hw = cm.TPU_V5E_POD
    for p in plans.values():
        assert p.n_layers > 0
        assert 1 <= p.avg_units <= hw.n_units
        assert all(len(vs.versions) >= 1 for vs in p.version_sets)
    names = list(plans)
    wl = poisson_workload(names, 40, 150, seed=0)
    m = Simulator(hw, plans, VeltairPolicy(hw)).run(wl)
    assert m.qos_rate > 0.9
    m2 = Simulator(hw, plans, ModelWisePolicy(hw)).run(wl)
    assert m.qos_rate >= m2.qos_rate


def test_tpu_cost_model_has_collective_term():
    from repro.configs import get_config, get_shape
    from repro.core.profiles import lm_layers
    from repro.core.schedule_space import enumerate_versions
    hw = cm.TPU_V5E_POD
    lay = lm_layers(get_config("gemma-2b"), get_shape("decode_32k"))[0]
    import dataclasses
    v = enumerate_versions(lay, hw)[0]
    itf0 = cm.Interference()
    # HBM pressure slows decode (memory-bound) latency
    itf_bw = cm.Interference(bw=2.0)
    assert cm.latency(hw, v, 8, itf_bw) > cm.latency(hw, v, 8, itf0)
    # ICI pressure slows comm-heavy versions (TP all-reduce dominated)
    v_comm = dataclasses.replace(v, comm_bytes_per_unit=1e9)
    itf_ici = cm.Interference(ici=2.0)
    assert cm.latency(hw, v_comm, 8, itf_ici) \
        > cm.latency(hw, v_comm, 8, itf0)
    # and the emitted link demand is nonzero for multi-chip placements
    assert cm.ici_demand(hw, v_comm, 8) > 0
