"""Property tests: layer-block formation (Alg. 2), thresholds, proxy."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import cost_model as cm
from repro.core import layer_block as lb
from repro.core.interference import (calibrate_proxy, pca_variance,
                                     pressure_on, RunningDemand,
                                     synthesize_counters)
from repro.serving.tenants import paper_plan


@pytest.fixture(scope="module")
def plan():
    return paper_plan("resnet50", "cpu")


def test_blocks_partition_layers(plan):
    hw = cm.CPU_3990X
    for thres in (0.0, 2.0, 8.0, 32.0, 1e9):
        blocks = lb.form_blocks(plan, hw, cm.Interference(), thres)
        # exact partition of [0, N)
        assert blocks[0].start == 0
        assert blocks[-1].end == plan.n_layers
        for a, b in zip(blocks, blocks[1:]):
            assert a.end == b.start
        # budgets partition the model QoS
        assert np.isclose(sum(b.budget_s for b in blocks), plan.qos_s)
        # every block respects the unit cap (within avg + thres)
        cap = min(int(plan.avg_units + thres) if thres < hw.n_units
                  else hw.n_units, hw.n_units)
        for b in blocks:
            assert 1 <= b.units <= max(cap, 1)


def test_higher_threshold_fewer_blocks(plan):
    hw = cm.CPU_3990X
    counts = [len(lb.form_blocks(plan, hw, cm.Interference(), t))
              for t in (0.0, 4.0, 16.0, 64.0)]
    assert counts == sorted(counts, reverse=True)
    # infinite threshold => model-wise (single block)
    assert len(lb.form_blocks(plan, hw, cm.Interference(), 1e9)) == 1


def test_finding_first_pivot():
    reqs = [10, 12, 30, 9, 9, 40, 11]
    assert lb.finding_first_pivot(reqs, avg_c=12, thres=5.0, start=0) == 2
    assert lb.finding_first_pivot(reqs, avg_c=12, thres=5.0, start=2) == 5
    assert lb.finding_first_pivot(reqs, avg_c=50, thres=50.0, start=0) == 7


def test_block_units_meet_budget_when_feasible(plan):
    hw = cm.CPU_3990X
    itf = cm.Interference()
    blocks = lb.form_blocks(plan, hw, itf, thres=16.0)
    for b in blocks:
        lat = b.latency(hw, b.units, itf)
        cap = int(plan.avg_units + 16.0)
        if b.units < cap:   # interior solution must meet its budget
            assert lat <= b.budget_s * 1.001


def test_avg_units_is_layer_mean(plan):
    hw = cm.CPU_3990X
    mean = sum(min(u, hw.n_units) for u in plan.layer_units) \
        / len(plan.layer_units)
    assert plan.avg_units == max(1, round(mean))


# --------------------------------------------------------------------------
# Interference proxy (paper Fig. 11)
# --------------------------------------------------------------------------
def test_proxy_accuracy_and_pca():
    hw = cm.CPU_3990X
    proxy, counters, levels = calibrate_proxy(hw, n=512)
    assert proxy.r2 > 0.95, f"proxy R2 too low: {proxy.r2}"
    var = pca_variance(counters[:, :2])
    # L3 counters dominate the variance (Fig. 11a: >99% with distractors)
    var_all = pca_variance(counters)
    assert var_all[0] + var_all[1] > 0.8


def test_pressure_on_excludes_self_and_soon_done():
    d = [RunningDemand(tenant=1, bw=0.4, cache=0.5, ici=0.0, start=0.0,
                       finish=10.0),
         RunningDemand(tenant=2, bw=0.3, cache=0.2, ici=0.0, start=0.0,
                       finish=10.0),
         RunningDemand(tenant=3, bw=0.2, cache=0.2, ici=0.0, start=0.0,
                       finish=1.0)]
    # at t=0.95 tenant-3's chunk is >90% done -> excluded
    itf = pressure_on(1, d, now=0.95)
    assert np.isclose(itf.bw, 0.3) and np.isclose(itf.cache, 0.2)
    itf2 = pressure_on(1, d, now=0.5)
    assert np.isclose(itf2.bw, 0.5) and np.isclose(itf2.cache, 0.4)


def test_interference_level_roundtrip():
    for x in (0.0, 0.3, 0.7, 1.0):
        itf = cm.Interference.from_level(x)
        assert abs(itf.level - x) < 1e-9
    assert cm.level_to_idx(0.0) == 0
    assert cm.level_to_idx(1.0) == cm.NUM_LEVELS - 1
    # grid/index round trip
    for i in range(cm.NUM_LEVELS):
        assert cm.level_to_idx(cm.grid_point(i)) == i
