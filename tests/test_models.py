"""Per-architecture smoke tests (reduced configs) + cache-path invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCH_NAMES, get_config, get_reduced_config,
                           SHAPES, shape_applicable)
from repro.configs.base import ShapeConfig
from repro.models import build_model, make_sample_inputs, param_count

SMOKE = ShapeConfig("smoke", seq_len=16, global_batch=2, mode="train")


def _nodrop(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=float(cfg.moe.num_experts)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_loss(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_sample_inputs(cfg, SMOKE)
    logits, aux = model.forward(params, batch)
    b, s = 2, 16
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_train_step(arch):
    from repro.training import TrainConfig, OptimizerConfig
    from repro.training.train_step import init_train_state, make_train_step
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    tc = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                               total_steps=4))
    state = init_train_state(model, jax.random.PRNGKey(0), tc)
    step = jax.jit(make_train_step(model, tc))
    batch = make_sample_inputs(cfg, SMOKE)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])  # same batch: must improve
    assert int(state["step"]) == 2


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_matches_forward(arch):
    cfg = _nodrop(get_reduced_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    full = make_sample_inputs(
        cfg, ShapeConfig("s", seq_len=S + 1, global_batch=B, mode="prefill"))
    logits_full, _ = model.forward(params, full)
    if "tokens" in full:
        pre = {"tokens": full["tokens"][:, :S]}
        step = {"tokens": full["tokens"][:, S]}
    else:
        pre = {"embeds": full["embeds"][:, :S]}
        if "positions" in full:
            pre["positions"] = full["positions"][..., :S]
        step = {"embeds": full["embeds"][:, S]}
    cache = model.init_cache(B, S + 1)
    lg_pre, cache = model.prefill(params, pre, cache)
    lg_dec, _ = model.decode_step(params, step, cache, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)


def test_chunked_attention_equals_unchunked():
    import repro.models.layers as L
    cfg = get_reduced_config("llama3-405b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_sample_inputs(cfg, SMOKE)
    logits_a, _ = model.forward(params, batch)
    old = L.SCORE_CHUNK_ELEMS
    try:
        L.SCORE_CHUNK_ELEMS = 32          # force chunking
        logits_b, _ = model.forward(params, batch)
    finally:
        L.SCORE_CHUNK_ELEMS = old
    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_tokens_but_stays_finite():
    cfg = get_reduced_config("arctic-480b")      # cf=1.25 -> drops happen
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_sample_inputs(cfg, SMOKE)
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(metrics["aux"]) > 0            # load-balance loss active


def test_full_config_param_counts():
    """Full (non-reduced) configs match the published parameter scale."""
    expected = {
        "llama3-405b": (390e9, 420e9),
        "arctic-480b": (450e9, 500e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "gemma-2b": (2.0e9, 3.0e9),
        "minicpm-2b": (2.2e9, 3.3e9),
        "starcoder2-3b": (2.8e9, 3.5e9),
        "mamba2-780m": (0.6e9, 0.9e9),
        "recurrentgemma-2b": (2.2e9, 3.4e9),
        "musicgen-large": (1.8e9, 2.6e9),
        "qwen2-vl-2b": (1.3e9, 2.4e9),
    }
    for arch, (lo, hi) in expected.items():
        n = param_count(build_model(get_config(arch)).param_specs())
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}-{hi/1e9}]"


def test_long_500k_applicability():
    runnable = [a for a, s, ok, _ in
                __import__("repro.configs", fromlist=["all_cells"]).all_cells(
                    include_skipped=True)
                if s == "long_500k" and ok]
    assert sorted(runnable) == ["mamba2-780m", "recurrentgemma-2b"]
