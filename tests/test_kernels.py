"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes/dtypes with hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@given(
    m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
    bm=st.sampled_from([8, 16, 32]), bk=st.sampled_from([16, 32]),
    bn=st.sampled_from([16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
@settings(max_examples=20, deadline=None)
def test_block_matmul_matches_ref(m, k, n, bm, bk, bn, dtype):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x = _rand(rng, (m, k), dtype)
    w = _rand(rng, (k, n), dtype)
    got = ops.block_matmul(x, w, bm=bm, bk=bk, bn=bn, interpret=True)
    want = ref.matmul_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


def test_block_matmul_batched_dims():
    rng = np.random.default_rng(0)
    x = _rand(rng, (2, 3, 24), jnp.float32)
    w = _rand(rng, (24, 16), jnp.float32)
    got = ops.block_matmul(x, w, bm=8, bk=8, bn=8, interpret=True)
    want = ref.matmul_ref(x, w)
    assert got.shape == (2, 3, 16)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    s=st.sampled_from([8, 17, 24]), t_extra=st.integers(0, 9),
    h=st.sampled_from([2, 4]), kv=st.sampled_from([1, 2]),
    d=st.sampled_from([8, 16]),
    window=st.sampled_from([None, 5, 16]),
    bq=st.sampled_from([4, 8]), bkv=st.sampled_from([4, 8]),
)
@settings(max_examples=20, deadline=None)
def test_flash_attention_matches_ref(s, t_extra, h, kv, d, window, bq, bkv):
    if h % kv:
        kv = 1
    t = s + t_extra
    rng = np.random.default_rng(s * 100 + t)
    q = _rand(rng, (2, s, h, d), jnp.float32)
    k = _rand(rng, (2, t, kv, d), jnp.float32)
    v = _rand(rng, (2, t, kv, d), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(s), (2, s))
    got = ops.flash_attention(q, k, v, q_positions=qpos, kv_valid_len=s,
                              window=window, bq=bq, bkv=bkv, interpret=True)
    want = ref.attention_ref(q, k, v, offset=0, kv_valid_len=s,
                             window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_decode_offset():
    rng = np.random.default_rng(3)
    q = _rand(rng, (2, 1, 4, 16), jnp.float32)
    k = _rand(rng, (2, 32, 2, 16), jnp.float32)
    v = _rand(rng, (2, 32, 2, 16), jnp.float32)
    got = ops.flash_attention(q, k, v, q_positions=jnp.full((2, 1), 20),
                              kv_valid_len=21, window=8, bq=8, bkv=8,
                              interpret=True)
    want = ref.attention_ref(q, k, v, offset=20, kv_valid_len=21, window=8)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(
    l=st.sampled_from([8, 24, 40]), h=st.sampled_from([1, 3]),
    p=st.sampled_from([4, 8]), n=st.sampled_from([4, 8]),
    chunk=st.sampled_from([4, 8, 16]), with_init=st.booleans(),
)
@settings(max_examples=20, deadline=None)
def test_ssd_scan_matches_ref(l, h, p, n, chunk, with_init):
    rng = np.random.default_rng(l * 7 + h)
    x = _rand(rng, (2, l, h, p), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (2, l, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    b = _rand(rng, (2, l, h, n), jnp.float32)
    c = _rand(rng, (2, l, h, n), jnp.float32)
    h0 = _rand(rng, (2, h, p, n), jnp.float32) if with_init else None
    y1, s1 = ops.ssd_scan(x, dt, a, b, c, chunk_size=chunk,
                          initial_state=h0, interpret=True)
    y2, s2 = ref.ssd_ref(x, dt, a, b, c, chunk_size=5,  # different chunking
                         initial_state=h0)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


def test_dispatch_interpret_mode_through_model():
    """The dispatch layer routes model math through the Pallas kernels in
    interpret mode and must agree with the pure-XLA path."""
    from repro.kernels import dispatch
    from repro.configs import get_reduced_config
    from repro.configs.base import ShapeConfig
    from repro.models import build_model, make_sample_inputs

    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_sample_inputs(
        cfg, ShapeConfig("s", seq_len=16, global_batch=2, mode="train"))
    logits_xla, _ = model.forward(params, batch)
    dispatch.set_mode("interpret")
    try:
        logits_k, _ = model.forward(params, batch)
    finally:
        dispatch.set_mode("xla")
    np.testing.assert_allclose(np.asarray(logits_k), np.asarray(logits_xla),
                               rtol=5e-2, atol=5e-2)
