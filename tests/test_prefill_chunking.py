"""Chunked, length-bucketed prefill quanta (ISSUE-5 acceptance).

(1) Chunked+padded admission is token-for-token identical to the
monolithic prefill path under staggered admissions and mixed prompt
lengths — across every cache family the engines serve (linear KV,
SSM state, RG-LRU recurrence + window ring buffer); the ring-buffer
wrap (prompt longer than the attention window) is exact too.
(2) A ``prompt_len_spread > 0`` workload served after ``warmup()``
performs ZERO jax retraces (the compiled prefill shapes are the bucket
table, not the prompt-length distribution) — xla and interpret modes.
(3) Admission validates prompt length: ``len >= max_len`` raises (the
old path silently corrupted the cache row via a clamped
``dynamic_update_slice``) and the runtimes count it as a conflict.
(4) Prefill is metered: a long-prompt admission advances the virtual
clock and TTFT, and prefill chunks interleave with co-resident decode
quanta instead of stalling them.
(5) Co-located tenants get per-tenant prompt streams (seed offset).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import cost_model as cm
from repro.core.scheduler import FixedBlockPolicy, VeltairPolicy
from repro.kernels import dispatch
from repro.models import build_model
from repro.serving import OnlineRuntime, Workload, build_paper_plans
from repro.serving.engine import Request, ServingEngine

HW = cm.CPU_3990X
TENANTS = ["resnet50", "googlenet"]
MAX_LEN = 32
# deliberately mixed: multi-chunk, padded tail, sub-chunk, non-pow2
PROMPT_LENS = (13, 7, 19, 5)
N_NEW = 4


@pytest.fixture(scope="module")
def plans():
    return build_paper_plans(TENANTS, HW)


@pytest.fixture(scope="module")
def models():
    built = {}
    for i, arch in enumerate(("gemma-2b", "mamba2-780m",
                              "recurrentgemma-2b")):
        cfg = get_reduced_config(arch)
        model = build_model(cfg)
        built[arch] = (cfg, model, model.init(jax.random.PRNGKey(i)))
    return built


@pytest.fixture(autouse=True)
def _clean_dispatch():
    yield
    dispatch.set_mode("xla")
    dispatch.clear_tile_overrides()


def _prompts(cfg, lens, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _staggered(engine, prompts):
    """Admissions at different steps into 2 slots (slot reuse included)."""
    reqs = [Request(rid=i, prompt=p, max_new_tokens=N_NEW)
            for i, p in enumerate(prompts)]
    pending = list(reqs)
    assert engine.admit_request(pending.pop(0), drain=True)
    engine.step()
    assert engine.admit_request(pending.pop(0), drain=True)
    engine.step()
    engine.step()
    engine.run_to_completion(pending)
    assert all(r.done for r in reqs)
    return reqs


# ---------------------------------------------------------------------------
# (1) token identity: chunked+bucketed == monolithic
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-780m",
                                  "recurrentgemma-2b"])
def test_chunked_prefill_token_identity(models, arch):
    cfg, _, params = models[arch]
    prompts = _prompts(cfg, PROMPT_LENS)
    mono = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                         chunked_prefill=False)
    want = _staggered(mono, prompts)
    chunk = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                          prefill_chunk_len=8)
    got = _staggered(chunk, prompts)
    for w, g in zip(want, got):
        assert g.output == w.output, (arch, g.rid, g.output, w.output)
    # the chunked engine really went through the bucketed path
    assert chunk.prefill_chunks > len(prompts)       # 13 and 19 split
    assert chunk.prefill_pad_tokens > 0              # 13, 19, 5 padded
    assert chunk.prefill_tokens == sum(PROMPT_LENS)


def test_chunked_prefill_token_identity_interpret(models):
    cfg, _, params = models["gemma-2b"]
    dispatch.set_mode("interpret")
    prompts = _prompts(cfg, PROMPT_LENS[:2])
    mono = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                         chunked_prefill=False)
    want = _staggered(mono, prompts)
    chunk = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                          prefill_chunk_len=8)
    got = _staggered(chunk, prompts)
    for w, g in zip(want, got):
        assert g.output == w.output, (g.rid, g.output, w.output)


def test_window_ring_wrap_chunked_matches_monolithic(models):
    """Prompt longer than the hybrid's attention window: chunked prefill
    must reproduce the ring-buffer eviction pattern bit-exactly."""
    cfg, model, params = models["recurrentgemma-2b"]
    window = cfg.rglru.window_size
    n, max_len = window + 13, 2 * window
    prompt = _prompts(cfg, (n,), seed=11)[0]

    def decode_tail(cache, logits, steps=4):
        out = [int(jnp.argmax(logits[0]))]
        t = n
        for _ in range(steps):
            logits, cache = model.decode_step(
                params, {"tokens": jnp.asarray([out[-1]], jnp.int32)},
                cache, jnp.int32(t))
            out.append(int(jnp.argmax(logits[0])))
            t += 1
        return out

    cache = model.init_cache(1, max_len)
    lg, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                              cache)
    want = decode_tail(cache, lg)

    cache = model.init_cache(1, max_len)
    done, c = 0, 16
    while done < n:
        valid = min(c, n - done)
        toks = np.zeros(c, np.int32)
        toks[:valid] = prompt[done:done + valid]
        lg, cache = model.prefill_chunk(
            params, {"tokens": jnp.asarray(toks)[None]}, cache,
            jnp.int32(done), jnp.int32(valid))
        done += valid
    assert decode_tail(cache, lg) == want


# ---------------------------------------------------------------------------
# (2) mixed-length serving with zero post-warmup retraces
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_mixed_length_serve_zero_retraces_after_warmup(models, plans, mode):
    cfg, _, params = models["gemma-2b"]
    dispatch.set_mode(mode)
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                           prefill_chunk_len=8)
    engine.warmup()                      # note: NO per-length prompt_lens
    vc = engine.version_cache
    traces0, misses0 = vc.traces, vc.misses
    runtime = OnlineRuntime(engine, VeltairPolicy(HW), plans, HW)
    wl = Workload.poisson(TENANTS, 60, 6, prompt_len=12, max_new_tokens=3,
                          seed=2, prompt_len_spread=9)
    assert len(set(wl.prompt_lengths())) > 1, "spread must mix lengths"
    m = runtime.serve(wl)
    assert m.n_queries == wl.n_queries
    assert vc.traces == traces0, "mixed lengths must not retrace"
    assert vc.misses == misses0, "every dispatch is a version-cache hit"
    assert runtime.prefill_quanta > 0
    assert engine.prefill_pad_tokens > 0, "bucket padding exercised"


# ---------------------------------------------------------------------------
# (3) admission-time length validation
# ---------------------------------------------------------------------------
def test_admission_boundary_lengths(models):
    cfg, _, params = models["gemma-2b"]
    engine = ServingEngine(cfg, params, batch_slots=1, max_len=16,
                           prefill_chunk_len=8)
    # longest admissible prompt: max_len - 1 (one row left for decode)
    ok = Request(rid=0, prompt=_prompts(cfg, (15,))[0], max_new_tokens=4)
    done = engine.run_to_completion([ok])
    assert done and ok.done and len(ok.output) >= 2
    # inadmissible: empty, exactly max_len, beyond max_len
    for n in (0, 16, 17):
        bad = Request(rid=1, prompt=_prompts(cfg, (n or 1,))[0][:n],
                      max_new_tokens=1)
        with pytest.raises(ValueError):
            engine.admit_request(bad)
    assert engine.rejected_invalid == 3
    # a rejected admission must not leak its slot
    assert engine._free_slot() is not None


def test_runtime_counts_oversized_prompts_as_conflicts(models, plans):
    cfg, _, params = models["gemma-2b"]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN)
    runtime = OnlineRuntime(engine, VeltairPolicy(HW), plans, HW)
    wl = Workload.poisson(TENANTS, 60, 4, prompt_len=MAX_LEN,
                          max_new_tokens=2, seed=1)
    m = runtime.serve(wl)
    assert runtime.conflicts == wl.n_queries
    assert m.conflict_rate == 1.0
    assert not runtime.records, "oversized prompts must be dropped"


# ---------------------------------------------------------------------------
# (4) prefill is metered: clock, TTFT, interleaving
# ---------------------------------------------------------------------------
def test_long_prompt_admission_advances_clock(models, plans):
    """Regression: admission used to be free in virtual time — now a
    17-token prompt at chunk 4 is five metered quanta before TTFT."""
    cfg, _, params = models["gemma-2b"]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                           prefill_chunk_len=4)
    runtime = OnlineRuntime(engine, VeltairPolicy(HW), plans, HW)
    wl = Workload([(0.0, "resnet50")], prompt_len=17, max_new_tokens=2)
    m = runtime.serve(wl)
    assert runtime.prefill_quanta == 5          # [4, 4, 4, 4, 1]
    rec = runtime.records[0]
    assert rec.ttft_s == pytest.approx(5 * runtime.step_dt)
    assert m.avg_ttft_s == pytest.approx(rec.ttft_s)
    # latency includes the metered prefill plus the decode steps
    assert rec.latency >= 7 * runtime.step_dt - 1e-12


def test_prefill_chunks_interleave_with_decode(models, plans):
    """Two same-length prompts back to back: the first request's decode
    must complete while the second prompt is still prefilling — a long
    admission no longer stalls a co-resident tenant's decode.

    Pinned to the FIFO scheduler: strict prefill/decode alternation is
    the mechanism under test.  The SLO scheduler deliberately makes a
    different (deadline-driven) choice here — its preemption ordering is
    covered by tests/test_slo_scheduling.py."""
    cfg, _, params = models["gemma-2b"]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                           prefill_chunk_len=4)
    runtime = OnlineRuntime(engine, FixedBlockPolicy(HW, 1), plans, HW,
                            scheduler="fifo")
    wl = Workload([(0.0, "resnet50"), (0.0, "resnet50")],
                  prompt_len=12, max_new_tokens=2)
    runtime.serve(wl)
    assert len(runtime.records) == 2
    first, second = sorted(runtime.records, key=lambda r: r.finish)
    assert first.ttft_s < second.ttft_s
    # the first request finished before the second's prefill completed
    assert first.finish < second.arrival + second.ttft_s
    assert runtime.prefill_quanta == 6          # 3 chunks per prompt


# ---------------------------------------------------------------------------
# (5) per-tenant prompt streams in the cluster
# ---------------------------------------------------------------------------
def test_cluster_tenant_prompts_differ_but_stay_deterministic():
    from repro.serving import ClusterRuntime, build_cluster
    archs = ["gemma-2b", "mamba2-780m"]
    tenants = build_cluster(archs, HW, batch_slots=2, max_len=MAX_LEN)
    runtime = ClusterRuntime(tenants, VeltairPolicy(HW), HW)
    wl = Workload.poisson(archs, 100, 6, prompt_len=6, max_new_tokens=2,
                          seed=4)
    tables = runtime.tenant_prompts(wl)
    assert not np.array_equal(tables[archs[0]], tables[archs[1]]), \
        "co-located tenants must not replay byte-identical prompts"
    again = runtime.tenant_prompts(wl)
    for a in archs:
        assert np.array_equal(tables[a], again[a]), "must stay deterministic"
    # and the cluster serves chunked admissions end to end
    m = runtime.serve(wl)
    assert m.aggregate.n_queries == wl.n_queries
    assert sum(m.prefill_quanta.values()) >= wl.n_queries
    assert m.aggregate.avg_ttft_s > 0.0
