"""Simulator invariants + policy behaviour on small workloads."""
import pytest

from repro.core import cost_model as cm
from repro.core.scheduler import (FixedBlockPolicy, LayerWisePolicy,
                                  ModelWisePolicy, PremaPolicy,
                                  VeltairPolicy)
from repro.serving import (SimConfig, Simulator, build_paper_plans,
                           poisson_workload, uniform_workload)

HW = cm.CPU_3990X


@pytest.fixture(scope="module")
def plans():
    return build_paper_plans(["resnet50", "googlenet"], HW)


ALL_POLICIES = [
    lambda: ModelWisePolicy(HW),
    lambda: LayerWisePolicy(HW),
    lambda: FixedBlockPolicy(HW, 6),
    lambda: VeltairPolicy(HW),
    lambda: VeltairPolicy(HW, adaptive_compile=False),
    lambda: VeltairPolicy(HW, adaptive_schedule=False),
    lambda: PremaPolicy(HW),
]


@pytest.mark.parametrize("pf", ALL_POLICIES)
def test_conservation_every_query_finishes(plans, pf):
    wl = poisson_workload(["resnet50", "googlenet"], 60, 120, seed=2)
    sim = Simulator(HW, plans, pf())
    m = sim.run(wl)
    assert len(sim.records) == len(wl), "every query must complete"
    assert sim.pool.free == sim.pool.total, "all units returned"
    assert not sim.running and not sim.pending and not sim.active
    assert m.qos_rate >= 0.0 and m.avg_latency_s > 0


def test_latency_increases_with_load(plans):
    lat = []
    for qps in (30, 120, 240):
        sim = Simulator(HW, plans, VeltairPolicy(HW))
        m = sim.run(poisson_workload(["resnet50"], qps, 150, seed=3))
        lat.append(m.avg_latency_s)
    assert lat[0] <= lat[1] <= lat[2]


def test_prema_is_temporal_single_tenant(plans):
    """PREMA runs one task at a time on the whole machine."""
    sim = Simulator(HW, plans, PremaPolicy(HW))
    orig = Simulator._try_start
    max_used = [0]

    def spy(self, task, now, events):
        r = orig(self, task, now, events)
        tenants = {c.task.tid for c in self.running}
        assert len(tenants) <= 1
        max_used[0] = max(max_used[0], self.pool.used)
        return r
    Simulator._try_start = spy
    try:
        sim.run(uniform_workload("resnet50", 40, 40))
    finally:
        Simulator._try_start = orig
    assert max_used[0] == HW.n_units


def test_straggler_mitigation_counts():
    plans = build_paper_plans(["googlenet"], HW)
    sim = Simulator(HW, plans, VeltairPolicy(HW),
                    SimConfig(straggler_prob=0.2, straggler_slowdown=10.0,
                              straggler_factor=3.0, seed=7))
    m = sim.run(poisson_workload(["googlenet"], 40, 120, seed=4))
    assert sim.stragglers > 0, "straggler path must trigger"
    assert len(sim.records) == 120


@pytest.mark.slow
def test_veltair_beats_static_on_heavy_mix():
    """The paper's headline direction: FULL > layer-wise(Planaria-ish) and
    model-wise under the heavy workload class."""
    from repro.configs.paper_suite import paper_models, WORKLOAD_CLASSES
    pm = paper_models()
    models = list(WORKLOAD_CLASSES["heavy"])
    plans = build_paper_plans(models, HW)
    weights = [1.0 / pm[m].qos_ms for m in models]
    wl = poisson_workload(models, 14, 250, seed=1, weights=weights)

    def rate(pf):
        return Simulator(HW, plans, pf).run(wl).qos_rate

    full = rate(VeltairPolicy(HW))
    lw = rate(LayerWisePolicy(HW))
    mw = rate(ModelWisePolicy(HW))
    assert full > lw, f"FULL {full} must beat layer-wise {lw}"
    assert full > mw, f"FULL {full} must beat model-wise {mw}"


def test_upgrade_mechanism_recovers_units(plans):
    """grow-on-free: chunks started below minimum get topped up."""
    sim = Simulator(HW, plans, LayerWisePolicy(HW))
    sim.run(poisson_workload(["resnet50"], 250, 200, seed=5))
    assert sim.conflicts > 0               # under pressure there are some
    assert sim.pool.free == sim.pool.total


def test_truncated_run_accounts_inflight_allocation(plans):
    """max_sim_time cutting the event loop must not drop the allocated
    unit-time of chunks still in flight (unit_efficiency would be
    overstated: their alloc never flows through _on_finish)."""
    from repro.serving import SimConfig

    cutoff = 1e-5                       # far below any chunk latency
    sim = Simulator(HW, plans, ModelWisePolicy(HW),
                    SimConfig(max_sim_time=cutoff))
    sim.run(uniform_workload("resnet50", 10.0, 1))
    assert sim.running, "chunk must still be in flight at the cut-off"
    # full start..finish hold, matching what _on_finish would charge (busy
    # flops were charged in full at dispatch)
    expect = sum(c.units * (c.finish - c.start) for c in sim.running)
    assert expect > 0.0
    assert sim.alloc_unit_time == pytest.approx(expect)
    assert sim.busy_unit_time <= sim.alloc_unit_time
