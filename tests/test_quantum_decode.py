"""Fused dispatch quanta: ``step_quantum(k)`` must be token-for-token
identical to ``k`` sequential ``step()`` calls — under staggered
admissions, mixed prompt lengths, mid-quantum completions (per-request
``max_new_tokens`` so rows freeze at different steps inside one quantum)
and level switches at quantum boundaries — in both the XLA reference
path and Pallas interpret mode.  The quantum boundary is also the host
boundary: exactly ONE device->host sync per fused call, and a full level
sweep after ``warmup()`` leaves the version-cache trace counter flat
with the fused quantum entries already present."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import cost_model as cm
from repro.kernels import dispatch
from repro.serving.engine import Request, ServingEngine

MAX_LEN = 32


def _sequential_reference(model, params, prompt, n_new):
    """One request alone through the raw model — the ground truth."""
    cache = model.init_cache(1, MAX_LEN)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
    out = [int(jnp.argmax(logits[0]))]
    t = len(prompt)
    for _ in range(n_new):
        logits, cache = model.decode_step(
            params, {"tokens": jnp.asarray([out[-1]], jnp.int32)}, cache,
            jnp.int32(t))
        out.append(int(jnp.argmax(logits[0])))
        t += 1
    return out
PROMPT_LENS = (3, 7, 2)          # deliberately misaligned
MAX_NEW = (6, 3, 5)              # rows complete at different quantum steps


@pytest.fixture(scope="module")
def setup():
    from repro.models import build_model
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in PROMPT_LENS]
    return cfg, model, params, prompts


@pytest.fixture(autouse=True)
def _clean_dispatch():
    yield
    dispatch.set_mode("xla")
    dispatch.clear_tile_overrides()


def _make_reqs(prompts):
    return [Request(rid=i, prompt=p, max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts, MAX_NEW))]


# The shared schedule: admissions and level switches happen only at
# quantum boundaries, so the fused and per-step runs see byte-identical
# request state at every boundary.  (quantum, level, admit_next) tuples.
SCHEDULE = [(2, 0.0, True), (3, 1.0, True), (4, 0.3, False),
            (2, 1.0, True), (4, 0.0, False), (8, 0.6, False),
            (8, 0.6, False), (8, 0.0, False)]


def _run_schedule(cfg, params, prompts, *, fused):
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                           quantum_buckets=(2, 4))
    reqs = _make_reqs(prompts)
    pending = list(reqs)
    for k, level, admit in SCHEDULE:
        if admit and pending:
            if engine.admit_request(pending[0], drain=True):
                pending.pop(0)
        engine.set_interference_level(level)
        if fused:
            engine.step_quantum(k)
        else:
            for _ in range(k):
                engine.step()
        if not pending and all(r.done for r in reqs):
            break
    assert all(r.done for r in reqs), "schedule must drain every request"
    return engine, reqs


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_quantum_identical_to_sequential_steps(setup, mode):
    cfg, _, params, prompts = setup
    dispatch.set_mode(mode)
    _, want = _run_schedule(cfg, params, prompts, fused=False)
    eng, got = _run_schedule(cfg, params, prompts, fused=True)
    for w, g in zip(want, got):
        assert g.output == w.output, (mode, g.rid, g.output, w.output)
    # the fused run really coarsened the dispatch unit
    assert eng.quantum_calls >= 3
    assert eng.tokens_per_sync > 1.0


def test_exactly_one_host_sync_per_quantum(setup):
    """Acceptance: the host blocks once per fused quantum — the sync
    counter advances by exactly 1 per step_quantum regardless of how many
    tokens the quantum decoded."""
    cfg, _, params, prompts = setup
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN)
    reqs = _make_reqs(prompts)
    engine.admit_request(reqs[0], drain=True)
    engine.admit_request(reqs[1], drain=True)
    while any(r is not None for r in engine.slot_req):
        syncs0, toks0 = engine.host_syncs, engine.tokens_decoded
        engine.step_quantum(4)
        assert engine.host_syncs == syncs0 + 1
        assert engine.tokens_decoded > toks0
    # per-step baseline: one sync per token
    engine2 = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN)
    engine2.admit_request(_make_reqs(prompts)[0], drain=True)
    s0 = engine2.host_syncs
    engine2.step()
    engine2.step()
    assert engine2.host_syncs == s0 + 2


def test_quanta_beyond_max_bucket_split_and_stay_exact(setup):
    """A quantum larger than the top K-bucket is executed in bucket-sized
    fused chunks (one sync each) and stays token-identical."""
    cfg, model, params, prompts = setup
    want = _sequential_reference(model, params, prompts[0], 9)
    engine = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN,
                           quantum_buckets=(1, 2))
    req = Request(rid=0, prompt=prompts[0], max_new_tokens=9)
    engine.admit_request(req, drain=True)
    calls = 0
    while not req.done:
        h = engine.begin_quantum(16)
        assert h.steps <= 2, "capped at the largest warmed bucket"
        engine.finish_quantum(h)
        calls += 1
    assert calls >= 5                      # 9 tokens in <=2-step chunks
    assert req.output[:10] == want[:10]


def test_row_budget_freezes_exactly_at_bucket_edge(setup):
    """K-bucket boundary: a row whose remaining budget equals the
    executed bucket exactly must emit precisely that many tokens and
    freeze — no off-by-one at the pow2 edge, and the next quantum picks
    it up at the right position."""
    cfg, model, params, prompts = setup
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                           quantum_buckets=(2, 4))
    # rid 0 needs exactly 4 more tokens (== the top bucket); rid 1 has
    # plenty — one fused call must retire rid 0 at the edge exactly
    r0 = Request(rid=0, prompt=prompts[0], max_new_tokens=4)
    r1 = Request(rid=1, prompt=prompts[1], max_new_tokens=9)
    engine.admit_request(r0, drain=True)
    engine.admit_request(r1, drain=True)
    h = engine.begin_quantum(4)
    assert h.steps == 4 and h.bucket == 4
    fin = engine.finish_quantum(h)
    assert [r.rid for r in fin] == [0], "rid 0 retires at the edge"
    assert len(r0.output) == 5            # prefill token + exactly 4
    assert h.row_steps[0] == 4 and h.row_steps[1] == 4
    want = _sequential_reference(model, params, prompts[0], 4)
    assert r0.output == want[:5]
    while not r1.done:
        engine.step_quantum(4)
    assert r1.output == _sequential_reference(model, params,
                                              prompts[1], 9)[:10]


def test_k_beyond_largest_warmed_bucket_selects_top_bucket(setup):
    """K-bucket boundary: requesting k past the largest warmed bucket
    dispatches the top bucket's executable (no new compile, no phantom
    bucket key) and leaves the remainder for further calls."""
    cfg, _, params, prompts = setup
    engine = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN,
                           quantum_buckets=(2, 4))
    engine.warmup()
    vc = engine.version_cache
    misses0 = vc.misses
    engine.admit_request(Request(rid=0, prompt=prompts[0],
                                 max_new_tokens=20), drain=True)
    h = engine.begin_quantum(16)
    assert h.steps == 4 and h.bucket == 4, "capped at the top bucket"
    engine.finish_quantum(h)
    assert vc.misses == misses0, "no executable built past the ladder"
    for entry in vc._entries.values():
        assert set(entry.quanta) <= {2, 4}, "no bucket key beyond warmed"


def test_mid_quantum_completion_frees_slot_for_next_admission(setup):
    """A row finishing mid-quantum frees its slot at the boundary, and
    the next admission into that slot is pristine (no leaked state from
    the frozen tail of the previous tenant)."""
    cfg, model, params, prompts = setup
    engine = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN)
    short = Request(rid=0, prompt=prompts[0], max_new_tokens=2)
    engine.admit_request(short, drain=True)
    engine.step_quantum(8)                 # freezes after 2 steps
    assert short.done
    assert engine._free_slot() == 0
    want = _sequential_reference(model, params, prompts[2], 4)
    nxt = Request(rid=1, prompt=prompts[2], max_new_tokens=4)
    engine.admit_request(nxt, drain=True)
    while not nxt.done:
        engine.step_quantum(4)
    assert nxt.output[:5] == want[:5]


def test_level_sweep_after_warmup_traces_flat_with_quanta(setup):
    """Acceptance: warmup pre-builds the fused K-buckets alongside the
    level table, so a full level sweep dispatching fused quanta performs
    zero traces and zero version-cache misses."""
    cfg, _, params, prompts = setup
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN,
                           quantum_buckets=(2, 4))
    engine.warmup(prompt_lens=(len(prompts[0]),))
    vc = engine.version_cache
    for entry in vc._entries.values():
        assert set(entry.quanta) == {2, 4}, "buckets prebuilt at warmup"
    traces0, misses0 = vc.traces, vc.misses
    engine.admit_request(Request(rid=0, prompt=prompts[0],
                               max_new_tokens=64), drain=True)
    for i in range(cm.NUM_LEVELS):
        engine.set_interference_level(cm.grid_point(i))
        engine.step_quantum(3)
    assert vc.traces == traces0, "no trace after warmup"
    assert vc.misses == misses0, "every fused dispatch is a cache hit"
    assert engine.quantum_calls == cm.NUM_LEVELS


def test_zero_budget_request_finishes_under_fused_dispatch(setup):
    """Degenerate admissions (max_new_tokens=0) must complete in fused
    mode exactly like the per-step loop (one decode then the finish
    check), not spin forever with a zero quantum budget."""
    cfg, _, params, prompts = setup

    def run(fused):
        engine = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN)
        req = Request(rid=0, prompt=prompts[0], max_new_tokens=0)
        engine.admit_request(req, drain=True)
        for _ in range(4):
            if req.done:
                break
            engine.step_quantum(4) if fused else engine.step()
        return req

    want, got = run(False), run(True)
    assert want.done and got.done, "zero-budget request must finish"
    assert got.output == want.output


def test_warmup_mid_serving_preserves_inflight_state(setup):
    """warmup() donates and rewrites the batched cache for its warm decode
    calls — resident request rows must be snapshotted and restored, so a
    mid-serving warmup never changes the tokens an in-flight request
    produces."""
    cfg, model, params, prompts = setup
    want = _sequential_reference(model, params, prompts[0], 6)
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN)
    req = Request(rid=0, prompt=prompts[0], max_new_tokens=6)
    engine.admit_request(req, drain=True)
    engine.step()
    engine.step()
    engine.warmup(prompt_lens=(len(prompts[0]),))   # mid-serving warmup
    while not req.done:
        engine.step_quantum(4)
    assert req.output[:7] == want[:7]


def test_admission_write_is_jitted_and_row_local(setup):
    """The O(row) admission path: repeated admissions reuse one compiled
    row-writer executable (slot index is traced, so slot 0 and slot 1
    share it) and never corrupt resident rows."""
    cfg, model, params, prompts = setup
    want = [_sequential_reference(model, params, p, 3) for p in prompts]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(prompts)]
    done = engine.run_to_completion(list(reqs))
    assert len(done) == len(reqs)
    for i, r in enumerate(reqs):
        assert r.output[:4] == want[i][:4]
