"""Autotuned tile ladder (ISSUE-8 tentpole, part 2).

(1) ``search_tile_ladder`` emits a valid LadderSpec: NUM_LEVELS levels,
finite predicted scores, the exclusive -> shared working-set invariant
holding *by construction* (the search caps candidates at the previous
level's footprint).
(2) The invariant is enforced, not advisory: ``validate()`` raises on a
growing working set, on a wrong level count, and on incomplete tilings;
``from_json`` rejects unknown schemas; ``dispatch.load_ladder`` rejects
malformed files.
(3) Round trip: search -> to_json -> file -> ``dispatch.load_ladder``
installs the process-global ladder -> an engine built afterwards serves
from it (and an explicit ``ladder=`` argument wins over the default
table).
(4) Warmup prebuilds every ladder level: a full level-grid sweep with
live decode quanta after ``warmup()`` performs ZERO retraces.
(5) The ``tools/autotune_ladder.py --smoke`` CLI is an end-to-end
search -> validate -> serialize check (the fast CI job runs it).
"""
import json
import subprocess
import sys

import pytest

from benchmarks.hillclimb import _attention_tiles, search_tile_ladder
from repro.core import cost_model as cm
from repro.core.multiversion import LADDER_SCHEMA, LadderSpec, _matmul_bytes
from repro.kernels import dispatch

HW = cm.CPU_3990X
SMOKE_TILES = (32, 64, 128, 256)


def _smoke_layer():
    return cm.GemmLayer(name="smoke512", m=512, k=512, n=512, itemsize=4,
                        weight_bytes=512 * 512 * 4)


@pytest.fixture(scope="module")
def spec():
    return search_tile_ladder(_smoke_layer(), HW, tiles=SMOKE_TILES)


@pytest.fixture(autouse=True)
def _clean_global_ladder():
    """Every test leaves the process-global ladder as it found it."""
    before = dispatch.active_ladder()
    yield
    dispatch.install_ladder(before)


# ---------------------------------------------------------------------------
# (1) search output
# ---------------------------------------------------------------------------
def test_search_emits_full_valid_ladder(spec):
    assert len(spec) == cm.NUM_LEVELS
    spec.validate()                      # must not raise
    assert len(spec.scores) == cm.NUM_LEVELS
    assert all(s > 0.0 for s in spec.scores)
    assert spec.hw == HW.name
    assert spec.meta["layer"] == "smoke512"
    assert spec.meta["tiles"] == list(SMOKE_TILES)
    # every level carries both ops, attention coupled to the matmul M-tile
    for lvl in spec.levels:
        assert set(lvl) == {"matmul", "attention"}
        assert lvl["attention"] == _attention_tiles(lvl["matmul"]["bm"])


def test_search_ladder_is_monotone_exclusive_to_shared(spec):
    sizes = [_matmul_bytes(lvl) for lvl in spec.levels]
    assert sizes == sorted(sizes, reverse=True)
    # the search explored: the shared end must actually cede footprint
    # relative to the exclusive end on this layer/tile-set
    assert sizes[-1] < sizes[0]


def test_search_scores_are_cost_model_latencies(spec):
    """Level 0's score is the zero-pressure latency of level 0's tiling —
    the search's objective, recomputable from the public cost model."""
    import repro.core.schedule_space as ss
    units = spec.meta["units"]
    cands = ss.enumerate_versions(_smoke_layer(), HW, tiles=SMOKE_TILES)
    kw = spec.levels[0]["matmul"]
    best = min((v for v in cands
                if (v.bm, v.bk, v.bn) == (kw["bm"], kw["bk"], kw["bn"])),
               key=lambda v: cm.latency(HW, v, units, cm.Interference()))
    assert spec.scores[0] == pytest.approx(
        cm.latency(HW, best, units, cm.Interference()))


def test_search_rejects_empty_candidate_set():
    # on VMEM-constrained hardware a tile set of only huge tiles is
    # infeasible (working set over the hard cache limit)
    big = cm.GemmLayer(name="big", m=4096, k=4096, n=4096, itemsize=4,
                       weight_bytes=4096 * 4096 * 4)
    with pytest.raises(ValueError, match="no feasible tile candidates"):
        search_tile_ladder(big, cm.TPU_V5E_POD, tiles=(4096,))


# ---------------------------------------------------------------------------
# (2) invariants are enforced
# ---------------------------------------------------------------------------
def _levels(bms):
    return [{"matmul": {"bm": bm, "bk": 64, "bn": 64},
             "attention": _attention_tiles(bm)} for bm in bms]


def test_validate_rejects_growing_working_set():
    bms = [64] * (cm.NUM_LEVELS - 1) + [256]      # grows at the shared end
    spec = LadderSpec(name="bad", hw=HW.name, levels=_levels(bms))
    with pytest.raises(ValueError, match="ordering violated"):
        spec.validate()


def test_validate_rejects_wrong_level_count_and_incomplete_tiling():
    with pytest.raises(ValueError, match="levels"):
        LadderSpec(name="short", hw=HW.name,
                   levels=_levels([64] * 3)).validate()
    levels = _levels([64] * cm.NUM_LEVELS)
    del levels[4]["matmul"]["bk"]
    with pytest.raises(ValueError, match="complete matmul"):
        LadderSpec(name="holey", hw=HW.name, levels=levels).validate()


def test_from_json_rejects_unknown_schema(spec):
    data = json.loads(spec.to_json())
    data["schema"] = LADDER_SCHEMA + 1
    with pytest.raises(ValueError, match="schema"):
        LadderSpec.from_json(json.dumps(data))


def test_load_ladder_rejects_malformed_file(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"name": "x"}))
    with pytest.raises(ValueError, match="levels"):
        dispatch.load_ladder(p)


# ---------------------------------------------------------------------------
# (3) round trip: emit -> JSON -> dispatch install -> engine
# ---------------------------------------------------------------------------
def test_roundtrip_json_file_to_dispatch(spec, tmp_path):
    path = spec.save(tmp_path / "ladder.json")
    back = LadderSpec.load(path)
    assert back.levels == spec.levels
    assert back.scores == pytest.approx(spec.scores)
    installed = dispatch.load_ladder(path)
    assert installed == spec.levels
    assert dispatch.active_ladder() == spec.levels
    dispatch.install_ladder(None)
    assert dispatch.active_ladder() is None


def test_tile_tables_are_distinct_in_level_order(spec):
    tables = spec.tile_tables()
    assert 1 <= len(tables) <= cm.NUM_LEVELS
    seen = []
    for t in tables:
        assert t not in seen
        seen.append(t)
    assert tables[0] == spec.levels[0]   # level order preserved


@pytest.fixture(scope="module")
def engine_factory():
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model

    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make(**kw):
        from repro.serving.engine import ServingEngine
        kw.setdefault("batch_slots", 2)
        kw.setdefault("max_len", 32)
        return ServingEngine(cfg, params, **kw)
    return make


def test_engine_consumes_installed_and_explicit_ladder(spec, engine_factory):
    # explicit argument: the engine's level->tiles map IS the spec's
    eng = engine_factory(ladder=spec)
    for i in range(cm.NUM_LEVELS):
        lv = cm.grid_point(i)
        assert eng.tiles_for_level(lv) == spec.tiles_for_level(lv)
    # process-global install: engines built afterwards pick it up
    dispatch.install_ladder(spec.levels)
    eng2 = engine_factory()
    assert eng2.tiles_for_level(0.0) == spec.tiles_for_level(0.0)
    dispatch.install_ladder(None)
    # a live engine snapshotted the ladder: the uninstall can't touch it
    assert eng2.tiles_for_level(0.0) == spec.tiles_for_level(0.0)


def test_engine_rejects_wrong_length_ladder(engine_factory):
    with pytest.raises(ValueError, match="levels"):
        engine_factory(ladder=_levels([64] * 2))


# ---------------------------------------------------------------------------
# (4) warmup prebuilds every level: zero post-warmup retraces
# ---------------------------------------------------------------------------
def test_warmup_prebuilds_ladder_zero_post_warmup_retraces(
        spec, engine_factory):
    from repro.serving.engine import Request

    eng = engine_factory(ladder=spec)
    eng.warmup()
    traces0 = eng.version_cache.traces
    eng.admit_request(Request(rid=0, prompt=[1, 2, 3, 4],
                              max_new_tokens=24))
    while eng.prefill_pending:
        eng.prefill_step()
    # full exclusive->shared sweep with live decode quanta at each level
    for i in range(cm.NUM_LEVELS):
        eng.set_interference_level(cm.grid_point(i))
        eng.finish_quantum(eng.begin_quantum(2, fused=True))
    assert eng.version_cache.traces == traces0, \
        "level sweep after warmup must never retrace"


# ---------------------------------------------------------------------------
# (5) the CLI smoke path (what the fast CI job runs)
# ---------------------------------------------------------------------------
def test_autotune_cli_smoke(tmp_path):
    out = tmp_path / "smoke.json"
    r = subprocess.run(
        [sys.executable, "tools/autotune_ladder.py", "--smoke",
         "--out", str(out)],
        capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr
    spec = LadderSpec.load(out)          # validates on load
    assert len(spec) == cm.NUM_LEVELS
    assert dispatch.load_ladder(out) == spec.levels
