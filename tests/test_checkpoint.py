"""Checkpointing: roundtrip, retention, atomicity, async, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, elastic_restore, reshard_plan


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((8, 16)), jnp.bfloat16),
        "scale": jnp.asarray(rng.standard_normal(16), jnp.float32),
        "nested": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    tree = _tree()
    ckpt.save(5, tree)
    restored, step = ckpt.restore(None, tree)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_k_retention(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, _tree(s))
    assert ckpt.steps() == [3, 4]


def test_atomicity_tmp_dirs_ignored(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, _tree())
    # simulate a crashed mid-save
    os.makedirs(tmp_path / "step_00000009.tmp")
    # and an uncommitted dir (no COMMITTED marker)
    os.makedirs(tmp_path / "step_00000007")
    assert ckpt.latest_step() == 1


def test_async_save(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save_async(3, _tree())
    ckpt.wait()
    assert ckpt.latest_step() == 3


def test_elastic_restore_between_meshes(tmp_path):
    """A checkpoint written under one topology restores under another —
    here 1-device meshes with different PartitionSpecs stand in for the
    256 -> 512 chip reshard (the code path is identical)."""
    from jax.sharding import PartitionSpec as P
    mesh_a = jax.make_mesh((1, 1), ("data", "model"))
    mesh_b = jax.make_mesh((1,), ("data",))
    tree = _tree()
    pspecs = {"w": P(None, None), "scale": P(None),
              "nested": {"step": P()}}
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(2, tree)
    restored, step = elastic_restore(ckpt, tree, pspecs, mesh_b)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))
    rep = reshard_plan(pspecs, mesh_a, mesh_b,
                       {"w": (8, 16), "scale": (16,),
                        "nested": {"step": ()}})
    assert rep.n_leaves == 3 and not rep.incompatible


def test_restore_missing_raises(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ckpt.restore(None, _tree())


def test_fault_tolerance_heartbeat_and_straggler():
    from repro.dist.fault_tolerance import HeartbeatMonitor, StragglerPolicy
    hb = HeartbeatMonitor(deadline_s=5.0)
    hb.beat(1, now=0.0)
    hb.beat(2, now=0.0)
    hb.beat(1, now=4.0)
    assert hb.sweep(now=6.0) == [2]
    assert hb.alive() == [1]
    sp = StragglerPolicy(factor=4.0)
    assert not sp.is_straggler(1.0, 3.9)
    assert sp.is_straggler(1.0, 4.1)
    assert sp.redo_cost(1.0) == 5.0
