"""SLO-tiered quantum scheduling + admission control (ISSUE-6).

(1) Bursty/diurnal arrival generation: deterministic per seed at
thousands of requests, with the burstiness knob actually raising
inter-arrival variance at equal offered load.
(2) Tier semantics: deadline-carrying QueryRecords, per-tier metrics
from the one shared summarize(), qps_at_qos as the headline rate.
(3) Preemption ordering: an interactive-tier admission arriving
mid-stream runs its first prefill chunk before any further batch-tier
decode quantum — and token streams stay identical to the FIFO
schedule's per-request outputs (scheduling reorders, never corrupts).
(4) Admission control: shed/deferred queries are counted, never
silently dropped.
(5) API redesign: ``add_request`` deprecates into ``admit_request``,
``step()``/``step_quantum`` ride the unified begin/finish path, and
``run_to_completion`` defaults to fused dispatch with identical tokens.
"""
import math
import warnings

import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core.qos import (DEFAULT_TIERS, TIER_ORDER, QueryRecord,
                            TierMetrics, tier_spec, summarize)
from repro.core.scheduler import FixedBlockPolicy, VeltairPolicy
from repro.serving import (AdmissionController, OnlineRuntime, Workload,
                           build_paper_plans, diurnal_workload,
                           gamma_poisson_workload)
from repro.serving.engine import Request, ServingEngine

HW = cm.CPU_3990X
TENANTS = ["resnet50", "googlenet"]
TIERS = {"resnet50": "interactive", "googlenet": "batch"}


@pytest.fixture(scope="module")
def plans():
    return build_paper_plans(TENANTS, HW)


@pytest.fixture(scope="module")
def engine_factory():
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model

    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make(**kw):
        kw.setdefault("batch_slots", 2)
        kw.setdefault("max_len", 32)
        return ServingEngine(cfg, params, **kw)
    return make


# ---------------------------------------------------------------------------
# (1) bursty / diurnal arrival generation
# ---------------------------------------------------------------------------
def test_gamma_poisson_is_deterministic_and_bursty():
    n = 3000
    a1 = gamma_poisson_workload(TENANTS, 500.0, n, burstiness=4.0, seed=9)
    a2 = gamma_poisson_workload(TENANTS, 500.0, n, burstiness=4.0, seed=9)
    assert a1 == a2, "same seed must replay identically"
    assert len(a1) == n
    times = np.array([t for t, _ in a1])
    assert np.all(np.diff(times) >= 0), "arrivals must be sorted"
    # equal offered load, higher variance: the burstiness knob must raise
    # the coefficient of variation of inter-arrival gaps above Poisson's
    smooth = gamma_poisson_workload(TENANTS, 500.0, n, burstiness=0.0,
                                    seed=9)
    g_b = np.diff(times)
    g_s = np.diff([t for t, _ in smooth])
    cv_b = g_b.std() / g_b.mean()
    cv_s = np.std(g_s) / np.mean(g_s)
    assert cv_b > 1.5 * cv_s, (cv_b, cv_s)
    # mean offered load stays comparable (within 2x either way)
    assert 0.5 < (times[-1] / ([t for t, _ in smooth][-1])) < 2.0


def test_diurnal_workload_modulates_rate():
    n = 4000
    arr = diurnal_workload(["m"], 1000.0, n, period_s=1.0, floor=0.1,
                           seed=3)
    assert arr == diurnal_workload(["m"], 1000.0, n, period_s=1.0,
                                   floor=0.1, seed=3)
    phase = np.array([t for t, _ in arr]) % 1.0
    # rate(t) peaks at phase 0.25 and troughs at 0.75
    peak = np.sum((phase > 0.0) & (phase < 0.5))
    trough = np.sum((phase > 0.5) & (phase < 1.0))
    assert peak > 2 * trough, (peak, trough)


def test_workload_constructors_carry_tiers():
    wl = Workload.bursty(TENANTS, 300, 50, seed=1, tiers=TIERS)
    assert wl.n_queries == 50
    assert wl.tier_of("resnet50") == "interactive"
    assert wl.tier_of("googlenet") == "batch"
    untiered = Workload.poisson(TENANTS, 300, 10)
    assert untiered.tier_of("resnet50") is None
    # trace replay sorts a recorded stream
    wl2 = Workload.replay([(0.5, "a"), (0.1, "b")], tiers={"a": "standard"})
    assert [t for t, _ in wl2.arrivals] == [0.1, 0.5]


# ---------------------------------------------------------------------------
# (2) tier semantics and the shared record schema
# ---------------------------------------------------------------------------
def test_tier_specs_scale_deadlines_in_order():
    scales = [DEFAULT_TIERS[t].deadline_scale for t in TIER_ORDER]
    assert scales == sorted(scales), "interactive tightest, batch loosest"
    assert DEFAULT_TIERS["batch"].sheddable is False
    assert DEFAULT_TIERS["interactive"].sheddable is True
    assert tier_spec(None) is DEFAULT_TIERS["standard"]
    with pytest.raises(ValueError):
        tier_spec("platinum")


def test_query_record_deadline_vs_legacy_satisfaction():
    legacy = QueryRecord("t", arrival=0.0, finish=0.5, qos_s=1.0)
    assert legacy.satisfied and legacy.deadline is None
    tiered = QueryRecord("t", arrival=0.0, finish=0.5, qos_s=0.1,
                         tier="batch", deadline=0.8)
    assert tiered.satisfied, "deadline overrides qos_s when set"
    late = QueryRecord("t", arrival=0.0, finish=0.9, qos_s=10.0,
                       tier="interactive", deadline=0.8)
    assert not late.satisfied


def test_summarize_reports_per_tier_and_qps_at_qos():
    recs = [QueryRecord("a", 0.0, 0.5, 1.0, tier="interactive",
                        deadline=1.0),
            QueryRecord("a", 0.0, 2.0, 1.0, tier="interactive",
                        deadline=1.0),
            QueryRecord("b", 0.0, 1.0, 1.0, tier="batch", deadline=8.0)]
    m = summarize(recs, 10.0, 0.0, 1.0, 2.0, shed=2, deferred=3)
    assert set(m.per_tier) == {"interactive", "batch"}
    assert isinstance(m.per_tier["interactive"], TierMetrics)
    assert m.per_tier["interactive"].n_queries == 2
    assert m.per_tier["interactive"].qos_rate == pytest.approx(0.5)
    assert m.per_tier["batch"].qos_rate == 1.0
    assert m.shed_queries == 2 and m.deferred_queries == 3
    # 2 satisfied over a 2.0s span
    assert m.qps_at_qos == pytest.approx(1.0)
    empty = summarize([], 10.0, 0.0, 0.0, 0.0, shed=5)
    assert empty.shed_queries == 5 and empty.qps_at_qos == 0.0


def test_metrics_schema_parity_online_vs_cluster(plans, engine_factory):
    """Per-tier qos_rate/TTFT/p99 report through the SAME schema from
    both runtimes: one QueryRecord shape, one summarize()."""
    from repro.serving import ClusterRuntime, build_cluster

    wl = Workload.bursty(TENANTS, 300, 12, prompt_len=4, max_new_tokens=2,
                         seed=4, tiers=TIERS)
    rt = OnlineRuntime(engine_factory(), VeltairPolicy(HW), plans, HW)
    m_online = rt.serve(wl)

    archs = ["gemma-2b", "mamba2-780m"]
    ctiers = {"gemma-2b": "interactive", "mamba2-780m": "batch"}
    cluster = ClusterRuntime(
        build_cluster(archs, HW, batch_slots=2, max_len=32, tiers=ctiers),
        VeltairPolicy(HW), HW)
    wl_c = Workload.bursty(archs, 300, 12, prompt_len=4, max_new_tokens=2,
                           seed=4)
    m_cluster = cluster.serve(wl_c).aggregate

    for m in (m_online, m_cluster):
        assert type(m).__name__ == "ServingMetrics"
        assert m.per_tier, "tiered serve must report per-tier slices"
        for tm in m.per_tier.values():
            assert isinstance(tm, TierMetrics)
            assert math.isfinite(tm.p99_latency_s)
        assert m.qps_at_qos > 0.0
    # tier labels land on the records themselves, identically shaped
    for recs in (rt.records, cluster.outputs):
        assert recs
    assert {r.tier for r in rt.records} <= set(TIER_ORDER)


# ---------------------------------------------------------------------------
# (3) preemption ordering + token identity (the tentpole property)
# ---------------------------------------------------------------------------
def test_interactive_prefill_preempts_batch_decode(plans, engine_factory):
    """A batch-tier stream is decoding; an interactive request arrives
    mid-stream.  Its first prefill chunk must be the next scheduled
    quantum — before any further batch-tier decode quantum."""
    wl = Workload(
        [(0.0, "googlenet"), (0.004, "resnet50")],
        prompt_len=12, max_new_tokens=8, tiers=TIERS)
    rt = OnlineRuntime(engine_factory(prefill_chunk_len=4),
                       FixedBlockPolicy(HW, 1), plans, HW)
    rt.serve(wl)
    t_arr = 0.004
    after = [ev for ev in rt.sched_trace if ev[-1] >= t_arr]
    assert after, "trace must cover the interactive arrival"
    first = after[0]
    assert first[0] == "prefill" and first[2] == "interactive", (
        f"interactive admission must preempt batch decode, got {first} "
        f"(trace after arrival: {after[:5]})")
    # and batch decode work did exist to preempt
    assert any(ev[0] == "decode" for ev in rt.sched_trace)


def test_slo_and_fifo_schedules_are_token_identical(plans, engine_factory):
    """Scheduling reorders quanta, never corrupts streams: per-request
    outputs under the SLO schedule match the FIFO schedule exactly."""
    wl = Workload.bursty(TENANTS, 400, 16, prompt_len=6, max_new_tokens=3,
                         seed=6, prompt_len_spread=3, tiers=TIERS)
    rt_slo = OnlineRuntime(engine_factory(), VeltairPolicy(HW), plans, HW,
                           scheduler="slo")
    rt_fifo = OnlineRuntime(engine_factory(), VeltairPolicy(HW), plans, HW,
                            scheduler="fifo")
    m_slo = rt_slo.serve(wl)
    m_fifo = rt_fifo.serve(wl)
    assert m_slo.n_queries == m_fifo.n_queries == wl.n_queries
    assert set(rt_slo.outputs) == set(rt_fifo.outputs)
    for rid in rt_fifo.outputs:
        assert rt_slo.outputs[rid] == rt_fifo.outputs[rid], rid
    # orderings did actually differ somewhere (otherwise the comparison
    # proves nothing) — prefill pick or admission order
    assert rt_slo.sched_trace != rt_fifo.sched_trace


def test_bad_scheduler_name_rejected(plans, engine_factory):
    with pytest.raises(ValueError):
        OnlineRuntime(engine_factory(), VeltairPolicy(HW), plans, HW,
                      scheduler="lifo")


# ---------------------------------------------------------------------------
# (4) admission control: counted, never silently dropped
# ---------------------------------------------------------------------------
def test_admission_control_sheds_and_defers_under_overload(
        plans, engine_factory):
    # one slot, a pile of simultaneous interactive arrivals: the ones
    # whose deadline is already hopeless at admission are shed
    wl = Workload([(i * 1e-4, "resnet50") for i in range(12)],
                  prompt_len=8, max_new_tokens=4,
                  tiers={"resnet50": "interactive"})
    rt = OnlineRuntime(engine_factory(batch_slots=1), VeltairPolicy(HW),
                       plans, HW, admission=AdmissionController())
    m = rt.serve(wl)
    assert m.shed_queries == rt.shed > 0
    assert m.deferred_queries == rt.deferred > 0
    # every arrival is accounted for: served or shed, nothing vanishes
    assert m.n_queries + m.shed_queries == wl.n_queries
    # shed requests never produced records
    assert len(rt.records) == m.n_queries


def test_no_admission_controller_means_no_shedding(plans, engine_factory):
    wl = Workload([(i * 1e-4, "resnet50") for i in range(8)],
                  prompt_len=8, max_new_tokens=4,
                  tiers={"resnet50": "interactive"})
    rt = OnlineRuntime(engine_factory(batch_slots=1), VeltairPolicy(HW),
                       plans, HW)
    m = rt.serve(wl)
    assert m.shed_queries == 0
    assert m.n_queries == wl.n_queries


def test_tier_qos_ordering_under_overload(plans, engine_factory):
    """Under sustained overload the SLO scheduler must privilege the
    tight tier: interactive qos_rate >= batch qos_rate (deterministic
    virtual-time serve)."""
    wl = Workload.bursty(TENANTS, 900, 30, burstiness=4.0, prompt_len=6,
                         max_new_tokens=4, seed=11, tiers=TIERS)
    rt = OnlineRuntime(engine_factory(), VeltairPolicy(HW), plans, HW,
                       admission=AdmissionController())
    m = rt.serve(wl)
    pt = m.per_tier
    assert "interactive" in pt and "batch" in pt
    assert pt["interactive"].qos_rate >= pt["batch"].qos_rate


# ---------------------------------------------------------------------------
# (5) the unified serving API
# ---------------------------------------------------------------------------
def test_add_request_deprecates_into_admit_request(engine_factory):
    engine = engine_factory()
    rng = np.random.default_rng(0)
    req = Request(rid=0, prompt=rng.integers(
        0, engine.cfg.vocab_size, 6).astype(np.int32), max_new_tokens=2)
    with pytest.warns(DeprecationWarning):
        assert engine.add_request(req)
    assert req.output, "shim must still drain the prefill"
    # the replacement spelling does the same without warning
    engine2 = engine_factory()
    req2 = Request(rid=0, prompt=req.prompt, max_new_tokens=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert engine2.admit_request(req2, drain=True)
    assert req2.output == req.output


def test_run_to_completion_fused_matches_per_step(engine_factory):
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 4096, n).astype(np.int32) for n in (5, 9, 7)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
    fused_engine = engine_factory()
    done_fused = fused_engine.run_to_completion(reqs())
    per_step_engine = engine_factory()
    done_step = per_step_engine.run_to_completion(reqs(), fused=False)
    assert len(done_fused) == len(done_step) == 3
    by_rid = lambda rs: {r.rid: r.output for r in rs}          # noqa: E731
    assert by_rid(done_fused) == by_rid(done_step)
    # fused default actually coarsened the host boundary
    assert fused_engine.tokens_per_sync > per_step_engine.tokens_per_sync
    assert fused_engine.quantum_calls > 0
    assert per_step_engine.quantum_calls == 0, \
        "per-step dispatch must not count as fused quantum calls"


def test_step_is_a_thin_wrapper_over_the_quantum_path(engine_factory):
    engine = engine_factory()
    rng = np.random.default_rng(2)
    req = Request(rid=0, prompt=rng.integers(
        0, engine.cfg.vocab_size, 4).astype(np.int32), max_new_tokens=3)
    engine.admit_request(req, drain=True)
    syncs0, calls0 = engine.host_syncs, engine.quantum_calls
    engine.step()
    assert engine.host_syncs == syncs0 + 1, "one sync per per-step dispatch"
    assert engine.quantum_calls == calls0, "step() is not a fused quantum"
    handle = engine.begin_quantum(2)
    assert handle is not None and handle.steps <= 2
    engine.finish_quantum(handle)
    assert engine.quantum_calls == calls0 + 1
