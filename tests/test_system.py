"""End-to-end behaviour tests for the full system."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_e2e_train_reduced_model(tmp_path):
    """Train a reduced model for a few steps via the real entry point."""
    from repro.configs import get_reduced_config
    from repro.data import DataConfig
    from repro.models import build_model
    from repro.training import OptimizerConfig, TrainConfig
    from repro.training.train_loop import LoopConfig, train_loop

    cfg = get_reduced_config("minicpm-2b")
    model = build_model(cfg)
    tc = TrainConfig(optimizer=OptimizerConfig(lr=2e-3, schedule="wsd",
                                               warmup_steps=3,
                                               total_steps=20),
                     accum_steps=2)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    out = train_loop(model, tc, dc,
                     LoopConfig(total_steps=20, ckpt_dir=str(tmp_path),
                                ckpt_every=10, log_every=5,
                                ), log=lambda *_: None)
    losses = [l for _, l in out["losses"]]
    assert losses[-1] < losses[0]
    # checkpoint was written and resume picks it up
    out2 = train_loop(model, tc, dc,
                      LoopConfig(total_steps=22, ckpt_dir=str(tmp_path),
                                 ckpt_every=10, log_every=1),
                      log=lambda *_: None)
    assert out2["losses"][0][0] >= 20


def test_e2e_multi_tenant_serving_sim():
    """Full multi-tenant pipeline: compile plans -> simulate -> metrics."""
    from repro.core import cost_model as cm
    from repro.core.qos import qps_at_qos
    from repro.core.scheduler import VeltairPolicy
    from repro.serving import Simulator, build_paper_plans, poisson_workload

    hw = cm.CPU_3990X
    plans = build_paper_plans(["resnet50", "googlenet"], hw)
    sweep = []
    for qps in (40, 80):
        sim = Simulator(hw, plans, VeltairPolicy(hw))
        m = sim.run(poisson_workload(["resnet50", "googlenet"], qps, 100,
                                     seed=0))
        sweep.append((qps, m))
    assert qps_at_qos(sweep, target=0.9) >= 40


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    """The dry-run lowers+compiles a cell on the 512-device mesh.  Runs in
    a subprocess so XLA_FLAGS never pollute this test process."""
    out = tmp_path / "cell.jsonl"
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-780m", "--shape", "decode_32k", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok", rec
    assert rec["n_devices"] == 256
    assert rec["cost"].get("flops", 0) > 0


def test_lm_profiles_flops_sane():
    """GEMM-reduced profiles match closed-form 6ND within tolerance."""
    from repro.configs import get_config, get_shape
    from repro.core.profiles import model_flops
    from repro.models import build_model, param_count

    cfg = get_config("gemma-2b")
    shape = get_shape("train_4k")
    n_params = param_count(build_model(cfg).param_specs())
    tokens = shape.global_batch * shape.seq_len
    fwd = model_flops(cfg, shape)
    # forward-only ~= 2*N*D (+attention); allow wide band
    assert 1.5 * n_params * tokens < fwd < 5.0 * n_params * tokens
