"""Co-location cluster path: ISSUE-3 acceptance properties.

(1) per-tick unit partitioning never exceeds (and under saturation
reaches) ``hw.n_units``, and every grant is returned; (2) per-engine
interference levels diverge under asymmetric load — the lightly-loaded
engine sees its heavy co-runner's pressure, not its own; (3) the
calibrated LinearProxy agrees with the oracle on calibration data, so
routing online decisions through it is sound; (4) a smoke co-location
serve completes in Pallas interpret mode with per-engine version caches.
"""
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core.interference import (Interference, calibrate_proxy,
                                     read_counters, synthesize_counters)
from repro.core.scheduler import ModelWisePolicy, PremaPolicy, VeltairPolicy
from repro.kernels import dispatch
from repro.serving import ClusterRuntime, Workload, build_cluster, cluster_plans

HW = cm.CPU_3990X
ARCHS = ["gemma-2b", "mamba2-780m"]


@pytest.fixture(scope="module")
def plans():
    return cluster_plans(ARCHS, HW)


@pytest.fixture(scope="module")
def cluster_factory(plans):
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import EngineTenant
    from repro.serving.engine import ServingEngine

    built = {}
    for arch in ARCHS:
        cfg = get_reduced_config(arch)
        model = build_model(cfg)
        built[arch] = (cfg, model.init(jax.random.PRNGKey(0)))

    def make(batch_slots=2):
        return [EngineTenant(
            name=a, plan=plans[a],
            engine=ServingEngine(built[a][0], built[a][1],
                                 batch_slots=batch_slots, max_len=32,
                                 version_sets=plans[a].version_sets))
            for a in ARCHS]
    return make


@pytest.fixture(autouse=True)
def _clean_overrides():
    yield
    dispatch.clear_tile_overrides()
    dispatch.set_mode("xla")


def test_partition_conserves_units(plans, cluster_factory):
    wl = Workload.poisson(ARCHS, 120, 14, prompt_len=4, max_new_tokens=3,
                          seed=1)
    runtime = ClusterRuntime(cluster_factory(), VeltairPolicy(HW), HW)
    m = runtime.serve(wl)
    assert m.aggregate.n_queries == wl.n_queries
    sums = [sum(p.values()) for p in m.partition_trace]
    assert max(sums) <= HW.n_units
    assert m.pool_peak_used <= HW.n_units
    # work-conserving under contention: some tick saturated the pool
    assert max(sums) > HW.n_units // 2
    # every grant was returned: the pool is whole again
    assert runtime.pool.free == runtime.pool.total
    # both engines actually got scheduling quanta and level decisions
    assert all(m.quanta[a] >= 1 for a in ARCHS)
    assert all(len(m.level_traces[a]) == m.quanta[a] for a in ARCHS)


def test_per_engine_levels_diverge_under_asymmetric_load(cluster_factory):
    """Victim semantics: the *lightly* loaded engine reads its heavy
    co-runner's slots as pressure, while the heavy engine sees almost
    none — so its level trace must sit strictly higher."""
    heavy, light = ARCHS
    arrivals = []
    t = 0.0
    for i in range(14):                       # keep the heavy engine full
        arrivals.append((t + i * 1e-3, heavy))
    arrivals.append((2e-3, light))
    arrivals.append((8e-3, light))
    wl = Workload(sorted(arrivals), prompt_len=4, max_new_tokens=4, seed=0)
    runtime = ClusterRuntime(cluster_factory(batch_slots=4),
                             VeltairPolicy(HW), HW)
    m = runtime.serve(wl)
    lv_heavy = m.mean_levels[heavy]
    lv_light = m.mean_levels[light]
    assert lv_light > lv_heavy, (
        f"light tenant should read co-runner pressure: {m.mean_levels}")
    # and the decisions reached the engines as distinct code versions
    assert len(m.level_traces[light]) >= 1
    assert m.aggregate.n_queries == wl.n_queries


def test_proxy_matches_oracle_on_calibration_data():
    proxy, counters, levels = calibrate_proxy(HW)
    assert proxy.r2 > 0.9
    preds = np.array([proxy.predict(c[:2]) for c in counters])
    assert float(np.abs(preds - levels).mean()) < 0.08
    # the policy's counter hook is the same proxy: a synthetic sample at a
    # known pressure must come back near that pressure
    policy = VeltairPolicy(HW, proxy=proxy)
    rng = np.random.default_rng(7)
    errs = []
    for x in (0.2, 0.5, 0.9):
        truth = Interference.from_level(x)
        vals = synthesize_counters(HW, truth, rng)
        sample = type("S", (), {"values": vals, "t": 0.0, "truth": truth})
        errs.append(abs(policy.level_from_counters(sample) - x))
    assert max(errs) < 0.15
    # ground truth stays out of the online decision: only the sample's
    # counter values matter
    sample_no_truth = type("S", (), {"values": vals, "t": 0.0,
                                     "truth": None})
    assert policy.level_from_counters(sample_no_truth) == \
        policy.level_from_counters(sample)


def test_read_counters_exposes_cosrunner_pressure_only():
    from repro.core.interference import RunningDemand
    rng = np.random.default_rng(0)
    demands = [RunningDemand(tenant=0, bw=0.5, cache=0.8, ici=0.0,
                             start=0.0, finish=10.0),
               RunningDemand(tenant=1, bw=0.1, cache=0.1, ici=0.0,
                             start=0.0, finish=10.0)]
    s0 = read_counters(HW, 0, demands, 1.0, rng)     # victim 0: sees only 1
    s1 = read_counters(HW, 1, demands, 1.0, rng)     # victim 1: sees only 0
    assert s1.truth.cache > s0.truth.cache
    assert s0.truth.bw == pytest.approx(0.1)
    assert s1.truth.bw == pytest.approx(0.5)


def test_baselines_share_loop_but_pin_solo_version(plans, cluster_factory):
    wl = Workload.poisson(ARCHS, 100, 8, prompt_len=4, max_new_tokens=2,
                          seed=2)
    for policy in (ModelWisePolicy(HW), PremaPolicy(HW)):
        runtime = ClusterRuntime(cluster_factory(), policy, HW)
        m = runtime.serve(wl)
        assert m.aggregate.n_queries == wl.n_queries
        assert all(lv == 0.0 for tr in m.level_traces.values() for lv in tr)
    # PREMA quanta are exclusive: no tick grants units to both engines
    for part in runtime.partition_trace:
        assert sum(1 for g in part.values() if g > 0) <= 1


def test_cluster_rejects_unknown_tenant(cluster_factory):
    wl = Workload([(0.0, "not-a-model")])
    runtime = ClusterRuntime(cluster_factory(), VeltairPolicy(HW), HW)
    with pytest.raises(KeyError):
        runtime.serve(wl)


def test_cluster_smoke_interpret_mode(plans, cluster_factory):
    """Co-location on the Pallas interpret path: distinct engines keep
    distinct compiled version entries and every query completes."""
    dispatch.set_mode("interpret")
    tenants = cluster_factory()
    wl = Workload.poisson(ARCHS, 150, 4, prompt_len=2, max_new_tokens=2,
                          seed=3)
    runtime = ClusterRuntime(tenants, VeltairPolicy(HW), HW)
    m = runtime.serve(wl)
    assert m.aggregate.n_queries == wl.n_queries
    assert m.aggregate.qos_rate >= 0.0
    for t in tenants:
        assert len(t.engine.version_cache) >= 1
