"""Shared test config.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device; only launch/dryrun.py forces 512.

``hypothesis`` is optional: the property-based modules importorskip it,
and the CI profile is only registered when the package is present, so a
bare environment (jax + numpy + pytest) still collects and runs the
whole suite."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings
except ImportError:          # property tests skip via pytest.importorskip
    settings = None
else:
    settings.register_profile("ci", max_examples=15, deadline=None)
    settings.load_profile("ci")
