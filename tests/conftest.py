"""Shared test config.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device; only launch/dryrun.py forces 512."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hypothesis import settings

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")
