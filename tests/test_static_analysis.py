"""Tests for repro.analysis — the static invariant checker CI gate.

Per rule: one known-bad fixture that must produce violations, one
known-good fixture that must come back clean, and a suppression pass
(the bad fixture with ``# veltair: ignore[...]`` comments injected must
come back clean-but-suppressed).  Plus: the CLI contract (nonzero exit
on bad fixtures, ``--json`` records), the whole-repo clean run the CI
gate depends on, and the typed ``StaticArgError`` boundary check at
``VersionCache.quantum``/``spec_quantum`` that complements the
``retrace-hazard`` rule dynamically.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "fixtures" / "static_analysis"
CLI = ROOT / "tools" / "check_static.py"

from repro.analysis import all_rules, run  # noqa: E402
from repro.serving.version_cache import (  # noqa: E402
    StaticArgError, VersionCache)

RULE_FIXTURES = {
    "host-sync-in-hot-path": "hotpath",
    "use-after-donation": "donation",
    "retrace-hazard": "retrace",
    "paged-leaf-coverage": "paging",
    "tile-table-atomicity": "tiles",
}


def run_on(*paths, rules=None):
    return run([str(p) for p in paths], rules)


def hits(report, rule_id):
    return [v for v in report.violations if v.rule_id == rule_id]


# ---------------------------------------------------------------------------
# rule corpus
# ---------------------------------------------------------------------------
def test_rule_catalog_complete():
    ids = set(all_rules())
    assert ids == {"syntax", "host-sync-in-hot-path", "use-after-donation",
                   "retrace-hazard", "paged-leaf-coverage",
                   "tile-table-atomicity"}


@pytest.mark.parametrize("rule_id,stem", sorted(RULE_FIXTURES.items()))
def test_bad_fixture_flags(rule_id, stem):
    report = run_on(FIXTURES / f"bad_{stem}.py")
    assert hits(report, rule_id), \
        f"bad_{stem}.py should violate {rule_id}"
    # and only that rule fires: fixtures are single-hazard by design
    assert {v.rule_id for v in report.violations} == {rule_id}


@pytest.mark.parametrize("rule_id,stem", sorted(RULE_FIXTURES.items()))
def test_good_fixture_clean(rule_id, stem):
    report = run_on(FIXTURES / f"good_{stem}.py")
    assert report.ok, [v.format() for v in report.violations]


@pytest.mark.parametrize("rule_id,stem", sorted(RULE_FIXTURES.items()))
def test_bad_fixture_suppressible(rule_id, stem, tmp_path):
    """Injecting a justified ignore comment above every violation line
    turns the bad fixture into a clean (but counted-suppressed) run."""
    src = (FIXTURES / f"bad_{stem}.py").read_text()
    report = run_on(FIXTURES / f"bad_{stem}.py")
    lines = src.splitlines()
    for ln in sorted({v.line for v in report.violations}, reverse=True):
        indent = len(lines[ln - 1]) - len(lines[ln - 1].lstrip())
        lines.insert(ln - 1, " " * indent
                     + f"# veltair: ignore[{rule_id}] fixture test")
    target = tmp_path / f"bad_{stem}.py"
    target.write_text("\n".join(lines) + "\n")
    suppressed = run_on(target)
    assert suppressed.ok, [v.format() for v in suppressed.violations]
    assert len(suppressed.suppressed) == len(report.violations)
    assert all(v.justified for v in suppressed.suppressed)


def test_syntax_rule_flags_and_resists_suppression(tmp_path):
    report = run_on(FIXTURES / "bad_syntax.py")
    assert hits(report, "syntax")
    # an unparseable file cannot argue its way out via comments
    bad = tmp_path / "still_bad.py"
    bad.write_text("# veltair: ignore[syntax] nope\ndef broken(:\n")
    assert not run_on(bad).ok


def test_good_hotpath_suppression_is_counted_and_justified():
    report = run_on(FIXTURES / "good_hotpath.py")
    assert report.ok
    assert len(report.suppressed) == 1
    v = report.suppressed[0]
    assert v.rule_id == "host-sync-in-hot-path" and v.justified


def test_unjustified_suppression_detected(tmp_path):
    f = tmp_path / "m.py"
    f.write_text(
        "import jax.numpy as jnp\n"
        "class ServingEngine:\n"
        "    def begin_quantum(self, k):\n"
        "        x = jnp.zeros((2,))\n"
        "        return int(x.sum())  # veltair: ignore[host-sync-in-hot-path]\n")
    report = run_on(f)
    assert report.ok and len(report.suppressed) == 1
    assert not report.suppressed[0].justified


# ---------------------------------------------------------------------------
# whole-repo gate
# ---------------------------------------------------------------------------
def test_repo_src_is_clean():
    report = run_on(ROOT / "src")
    assert report.ok, "\n".join(v.format() for v in report.violations)
    # every live suppression in src/ must carry a justification
    assert all(v.justified for v in report.suppressed), \
        [v.format() for v in report.suppressed if not v.justified]


@pytest.mark.slow
def test_repo_wide_sweep_is_clean():
    report = run_on(ROOT / "src", ROOT / "examples", ROOT / "benchmarks",
                    ROOT / "tools")
    assert report.ok, "\n".join(v.format() for v in report.violations)


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------
def _cli(*args):
    return subprocess.run([sys.executable, str(CLI), *args],
                          capture_output=True, text=True, cwd=ROOT)


@pytest.mark.parametrize("stem", sorted(RULE_FIXTURES.values()) + ["syntax"])
def test_cli_exits_nonzero_on_bad_fixture(stem):
    proc = _cli(str(FIXTURES / f"bad_{stem}.py"))
    assert proc.returncode == 1, proc.stdout + proc.stderr


def test_cli_exit_zero_on_good_fixture_and_json_records():
    proc = _cli("--json", str(FIXTURES / f"bad_retrace.py"))
    assert proc.returncode == 1
    data = json.loads(proc.stdout)
    assert data["ok"] is False and data["violations"]
    rec = data["violations"][0]
    assert {"file", "line", "col", "rule", "message"} <= set(rec)
    assert rec["rule"] == "retrace-hazard"

    proc = _cli("--json", str(FIXTURES / "good_retrace.py"))
    assert proc.returncode == 0
    assert json.loads(proc.stdout)["ok"] is True


def test_cli_rules_filter_and_listing():
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    assert "host-sync-in-hot-path" in proc.stdout
    # rule filter: only syntax runs -> retrace fixture passes
    proc = _cli("--rules", "syntax", str(FIXTURES / "bad_retrace.py"))
    assert proc.returncode == 0
    proc = _cli("--rules", "no-such-rule", str(FIXTURES / "bad_retrace.py"))
    assert proc.returncode == 2
    assert "unknown rule" in proc.stderr


def test_cli_missing_path_is_one_line_error():
    proc = _cli("definitely/not/a/path")
    assert proc.returncode == 2
    assert "no such path" in proc.stderr


# ---------------------------------------------------------------------------
# K-bucket static-arg hashability at the VersionCache boundary (rule 3's
# dynamic complement): typed error instead of a silent per-value retrace
# ---------------------------------------------------------------------------
def test_version_cache_rejects_bad_static_keys():
    vc = VersionCache(model=None)   # validation fires before any build
    with pytest.raises(StaticArgError):
        vc.quantum(None, [4], None, None, 1)        # unhashable
    with pytest.raises(StaticArgError):
        vc.quantum(None, 3, None, None, 1)          # non-pow2
    with pytest.raises(StaticArgError):
        vc.quantum(None, True, None, None, 1)       # bool masquerading
    with pytest.raises(StaticArgError):
        vc.quantum(None, 4.0, None, None, 1)        # float key
    with pytest.raises(StaticArgError):
        vc.quantum(None, 0, None, None, 1)          # below minimum
    with pytest.raises(StaticArgError):
        vc.spec_quantum(None, 6, 2, None, None, 1)  # non-pow2 k
    with pytest.raises(StaticArgError):
        vc.spec_quantum(None, 4, 0, None, None, 1)  # depth < 1
    # the typed error is still a TypeError for generic callers
    assert issubclass(StaticArgError, TypeError)
