"""Known-bad fixture: non-atomic mutations of dispatch override state."""
from repro.kernels import dispatch
from repro.kernels.dispatch import _TILE_OVERRIDES, set_tile_overrides


def apply_level(level):
    # BAD: per-op install — N calls leave N-1 torn intermediate states
    set_tile_overrides("matmul", bm=256)
    dispatch.set_tile_overrides("attention", bq=128)
    # BAD: direct pokes at the shared table
    _TILE_OVERRIDES["flash"] = {"bq": 64}
    _TILE_OVERRIDES.clear()
    dispatch._LADDER = [level]
