"""Known-bad fixture: does not parse (rule 0 replaces compileall)."""
def broken(:
    return
