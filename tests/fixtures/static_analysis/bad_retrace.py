"""Known-bad fixture: unbucketed shapes / unhashable statics retrace."""
import jax
import jax.numpy as jnp


def serve(cache, entry, prompt, steps):
    # BAD: raw step count -> one AOT compile per distinct value
    fn = cache.quantum(entry, steps, None, None, 1)
    # BAD: per-request length -> one trace per distinct prompt length
    pad = jnp.zeros((len(prompt), 4))
    # BAD: mutable literal at a static position
    out = jax.jit(lambda x, cfg: x, static_argnums=(1,))(pad, [1, 2, 3])
    return fn, out
