"""Known-good fixture: every compiled-shape knob is visibly bucketed."""
import jax.numpy as jnp

QUANTUM_BUCKETS = (1, 2, 4, 8, 16)


def _next_pow2(n):
    p = 1
    while p < n:
        p *= 2
    return p


def serve(cache, entry, prompt, steps):
    bucket = next(b for b in QUANTUM_BUCKETS if b >= steps)
    fn = cache.quantum(entry, bucket, None, None, 1)        # bucketed
    top = cache.spec_quantum(entry, QUANTUM_BUCKETS[-1], 2,
                             None, None, 1)                 # bucket subscript
    lit = cache.quantum(entry, 4, None, None, 1)            # int literal
    pad = jnp.zeros((_next_pow2(len(prompt)), 4))           # sanctioned helper
    return fn, top, lit, pad


def warm(cache, entry, buckets):
    for k in buckets:                 # loop over a *bucket* collection
        cache.quantum(entry, k, None, None, 1)
