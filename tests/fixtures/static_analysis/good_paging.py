"""Known-good fixture: every "seq"-axis cache family is wired into the
Model.cache_specs dispatch (and paged_cache_specs calls back into it)."""


class ParamSpec:
    def __init__(self, shape, dtype=None, axes=(), init=None):
        self.shape, self.axes = shape, axes


def _attn_cache_specs(batch, t_max):
    return {"k": ParamSpec((batch, t_max, 4), None,
                           ("batch", "seq", "head_dim"))}


def _mla_cache_specs(batch, t_max):
    return {"c_kv": ParamSpec((batch, t_max, 8), None,
                              ("batch", "seq", "kv_lora"))}


def window_cache_specs(batch, w):
    # ring-buffer window cache: no "seq" axis, intentionally unpaged,
    # but still wired into the dispatch below
    return {"k": ParamSpec((batch, w, 4), None,
                           ("batch", "window", "head_dim"))}


class Model:
    def cache_specs(self, batch, t_max):
        specs = _attn_cache_specs(batch, t_max)
        specs.update(_mla_cache_specs(batch, t_max))
        specs.update(window_cache_specs(batch, 16))
        return specs

    def paged_cache_specs(self, batch, t_max):
        # calls INTO the anchor: connected, not reachable-from — fine
        return self.cache_specs(batch, t_max)
