"""Known-good fixture: the repo's donation idiom — rebind the donated
binding in the same statement, never touch the old handle again."""
import jax
import jax.numpy as jnp


def _writer():
    def write(cache, row):
        return cache.at[0].set(row)
    return jax.jit(write, donate_argnums=(0,))


class Engine:
    def __init__(self):
        self._row_writer = _writer()
        self.cache = jnp.zeros((4, 4))

    def admit(self, row):
        # same-statement rebind: the donated binding is replaced by the
        # result before anything can read it
        self.cache = self._row_writer(self.cache, row)
        return self.cache.shape


def direct():
    step = jax.jit(lambda c: c + 1, donate_argnums=(0,))
    cache = jnp.zeros((8,))
    cache = step(cache)
    return cache
