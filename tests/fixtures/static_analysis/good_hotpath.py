"""Known-good fixture: hot path stays async; host work is np-typed or
suppressed with justification; cold paths may sync freely."""
import jax
import jax.numpy as jnp
import numpy as np


class QuantumHandle:
    block: jax.Array


class ServingEngine:
    def begin_quantum(self, k):
        logits = jnp.zeros((4, 4))
        counts = np.zeros(4)                       # host array: fine
        total = float(counts.sum())                # numpy coercion: fine
        dims = int(logits.shape[0])                # static metadata: fine
        return logits, total, dims

    def finish_quantum(self, handle: QuantumHandle):
        # veltair: ignore[host-sync-in-hot-path] THE sanctioned per-quantum sync
        block = np.asarray(handle.block)
        return block

    def warmup(self):
        # not reachable from any hot root: syncing here is fine
        x = jnp.zeros((4,))
        x.block_until_ready()
        return int(x.sum())
