"""Known-good fixture: override state changes only through the atomic
whole-table installers (or a scoped context)."""
from repro.kernels import dispatch


def apply_level(level, tiles):
    dispatch.install_tile_overrides(
        {"matmul": {"bm": 256}, "attention": {"bq": 128}})
    dispatch.install_ladder([tiles])
    with dispatch.tile_context({"matmul": {"bm": 128}}):
        pass
    dispatch.clear_tile_overrides()
