"""Known-bad fixture: host syncs inside the quantum hot path."""
import jax
import jax.numpy as jnp
import numpy as np


class QuantumHandle:
    block: jax.Array


class ServingEngine:
    def begin_quantum(self, k):
        logits = jnp.zeros((4, 4))
        tok = int(jnp.argmax(logits[0]))          # BAD: int() coercion
        probe = logits.max().item()               # BAD: .item()
        if logits:                                # BAD: implicit truth sync
            pass
        return self.helper(logits), tok, probe

    def helper(self, logits: jax.Array):
        # reached from begin_quantum -> still hot path
        return np.asarray(logits)                 # BAD: np.asarray transfer

    def finish_quantum(self, handle: QuantumHandle):
        handle.block.block_until_ready()          # BAD: pipeline stall
        return jax.device_get(handle.block)       # BAD: device_get
