"""Known-bad fixture: reads of a buffer after it was donated."""
import jax
import jax.numpy as jnp


def _writer():
    def write(cache, row):
        return cache.at[0].set(row)
    return jax.jit(write, donate_argnums=(0,))


class Engine:
    def __init__(self):
        self._row_writer = _writer()
        self.cache = jnp.zeros((4, 4))

    def admit(self, row):
        new_cache = self._row_writer(self.cache, row)
        stale = self.cache.sum()          # BAD: self.cache was donated
        self.cache = new_cache
        return stale


def direct():
    step = jax.jit(lambda c: c + 1, donate_argnums=(0,))
    cache = jnp.zeros((8,))
    out = step(cache)
    return cache + out                    # BAD: cache was donated
