"""Known-bad fixture: a new cache family whose "seq"-axis specs never
flow through Model.cache_specs — paging can't see its leaves."""


class ParamSpec:
    def __init__(self, shape, dtype=None, axes=(), init=None):
        self.shape, self.axes = shape, axes


def _attn_cache_specs(batch, t_max):
    return {"k": ParamSpec((batch, t_max, 4), None,
                           ("batch", "seq", "head_dim"))}


def orphan_cache_specs(batch, t_max):
    # BAD: "seq"-axis cache leaves, but nothing in Model.cache_specs
    # dispatches here -> paged_leaf_paths never includes them
    return {"x": ParamSpec((batch, t_max, 8), None,
                           ("batch", "seq", "inner"))}


class Model:
    def cache_specs(self, batch, t_max):
        return _attn_cache_specs(batch, t_max)
