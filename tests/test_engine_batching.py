"""Per-slot continuous batching: staggered mixed-length prompts must be
token-for-token identical to a sequential one-request-at-a-time reference
(in both the XLA reference path and Pallas interpret mode), and level
flips after warmup() must be dictionary swaps — zero new traces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.kernels import dispatch
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine

PROMPT_LENS = (3, 7, 5)          # deliberately misaligned
N_NEW = 4
MAX_LEN = 32


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in PROMPT_LENS]
    return cfg, model, params, prompts


@pytest.fixture(autouse=True)
def _clean_dispatch():
    yield
    dispatch.set_mode("xla")
    dispatch.clear_tile_overrides()


def _sequential_reference(model, params, prompt, n_new):
    """One request alone through the raw model: the ground truth any
    batched/staggered schedule must reproduce exactly."""
    cache = model.init_cache(1, MAX_LEN)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
    out = [int(jnp.argmax(logits[0]))]
    t = len(prompt)
    for _ in range(n_new):
        logits, cache = model.decode_step(
            params, {"tokens": jnp.asarray([out[-1]], jnp.int32)}, cache,
            jnp.int32(t))
        out.append(int(jnp.argmax(logits[0])))
        t += 1
    return out


def _staggered_run(cfg, params, prompts):
    """Admit requests at different steps into a 2-slot engine (so slot
    reuse happens too) and run to completion."""
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=N_NEW)
            for i, p in enumerate(prompts)]
    assert engine.admit_request(reqs[0], drain=True)
    engine.step()                          # slot 0 is one token ahead
    assert engine.admit_request(reqs[1], drain=True)     # different length, later join
    engine.step()
    engine.step()
    engine.run_to_completion([reqs[2]])    # admitted after a slot frees
    assert all(r.done for r in reqs)
    return engine, reqs


@pytest.mark.parametrize("mode", ["xla", "interpret"])
def test_misaligned_prompts_match_sequential_reference(setup, mode):
    cfg, model, params, prompts = setup
    dispatch.set_mode(mode)
    want = [_sequential_reference(model, params, p, N_NEW) for p in prompts]
    _, reqs = _staggered_run(cfg, params, prompts)
    for i, req in enumerate(reqs):
        assert req.output[:N_NEW + 1] == want[i][:N_NEW + 1], \
            (mode, i, req.output, want[i])


def test_slot_reuse_cannot_leak_previous_request(setup):
    """A short prompt admitted into a slot previously used by a longer
    request must match its solo output (pristine-row admission)."""
    cfg, model, params, _ = setup
    rng = np.random.default_rng(11)
    long_p = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    short_p = rng.integers(0, cfg.vocab_size, 2).astype(np.int32)
    want = _sequential_reference(model, params, short_p, N_NEW)
    engine = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN)
    engine.run_to_completion([Request(rid=0, prompt=long_p,
                                      max_new_tokens=N_NEW)])
    req = Request(rid=1, prompt=short_p, max_new_tokens=N_NEW)
    engine.run_to_completion([req])
    assert req.output[:N_NEW + 1] == want[:N_NEW + 1]


def test_full_level_sweep_after_warmup_zero_retraces(setup):
    """Acceptance: after warmup(), sweeping every interference level and
    stepping performs zero retraces — each switch is a cache hit."""
    cfg, _, params, prompts = setup
    from repro.core import cost_model as cm
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN)
    engine.warmup(prompt_lens=tuple(len(p) for p in prompts))
    vc = engine.version_cache
    traces0, misses0 = vc.traces, vc.misses
    switches0 = engine.level_switches
    engine.admit_request(Request(rid=0, prompt=prompts[0],
                               max_new_tokens=64), drain=True)
    for i in range(cm.NUM_LEVELS):
        engine.set_interference_level(cm.grid_point(i))
        engine.step()
    for i in range(4):                      # and repeated flips
        engine.set_interference_level(float(i % 2))
        engine.step()
    assert engine.level_switches > switches0, "flips must register"
    assert vc.misses == misses0, "every switch must be a cache hit"
    assert vc.traces == traces0, "no new traces after warmup"


def test_interpret_mode_flips_hit_distinct_version_entries(setup):
    """Under a Pallas dispatch mode each tile table gets its own compiled
    entry (xla mode collapses them — tiles don't affect the reference
    path), and flips after warming those entries never retrace."""
    cfg, _, params, prompts = setup
    dispatch.set_mode("interpret")
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN)
    engine.warmup(prompt_lens=(len(prompts[0]),), levels=[0.0, 1.0])
    vc = engine.version_cache
    assert len(vc) == 3                 # baseline {} + two tile tables
    traces0, misses0 = vc.traces, vc.misses
    engine.admit_request(Request(rid=0, prompt=prompts[0],
                               max_new_tokens=64), drain=True)
    for i in range(4):
        engine.set_interference_level(float(i % 2))
        engine.step()
    assert vc.misses == misses0 and vc.traces == traces0
    assert vc.hits >= 4


def test_version_cache_shared_per_tiles_not_per_switch(setup):
    cfg, _, params, _ = setup
    dispatch.set_mode("interpret")      # xla mode collapses keys
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=MAX_LEN)
    engine.set_interference_level(0.0)
    engine.set_interference_level(1.0)
    n_entries = len(engine.version_cache)
    assert n_entries == 3               # baseline {} + two tile tables
    for lv in (0.0, 1.0, 0.0, 1.0):
        engine.set_interference_level(lv)
    assert len(engine.version_cache) == n_entries
    assert engine.version_cache.hits >= 4


def test_two_engines_do_not_invalidate_each_other(setup):
    """Per-engine override contexts: engine B switching levels must not
    change what engine A's compiled executables produce."""
    cfg, model, params, prompts = setup
    dispatch.set_mode("interpret")
    want = _sequential_reference(model, params, prompts[0], N_NEW)
    eng_a = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN)
    eng_b = ServingEngine(cfg, params, batch_slots=1, max_len=MAX_LEN)
    req = Request(rid=0, prompt=prompts[0], max_new_tokens=N_NEW)
    eng_a.admit_request(req, drain=True)
    eng_b.set_interference_level(1.0)      # B stomps the global table
    while not req.done:
        eng_a.step()
    assert req.output[:N_NEW + 1] == want[:N_NEW + 1]


def test_atomic_override_install_clears_stale_ops():
    """Switching from the default source ({matmul, attention}) to a
    matmul-only table must clear the attention entry."""
    dispatch.install_tile_overrides(
        {"matmul": {"bm": 64}, "attention": {"bq": 64}})
    assert dispatch.tile_overrides("attention")
    dispatch.install_tile_overrides({"matmul": {"bm": 32}})
    assert dispatch.tile_overrides("attention") == {}
    assert set(dispatch.all_tile_overrides()) == {"matmul"}


def test_tile_context_is_atomic_and_scoped():
    dispatch.install_tile_overrides({"attention": {"bq": 64}})
    with dispatch.tile_context({"matmul": {"bm": 16}}):
        # inside a context, ops it does not name have NO override
        assert dispatch.tile_overrides("matmul") == {"bm": 16}
        assert dispatch.tile_overrides("attention") == {}
        assert set(dispatch.all_tile_overrides()) == {"matmul"}
    assert dispatch.tile_overrides("attention") == {"bq": 64}
